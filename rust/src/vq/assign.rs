//! Candidate-assignment search (Eq. 5) and ratio-logit init (Eq. 7).
//!
//! The AOT `init_assign` artifact does this on the device path (Pallas
//! distance kernel); this host implementation backs the pure-Rust
//! baselines, the Table-7 initialization ablation (random / cosine /
//! Euclidean), and the coordinator's unit tests.
//!
//! §Perf: the Euclid sweep at `d >= ops::PRUNE_MIN_D` replaces the full
//! `(s, k)` scratch table with a running top-n buffer plus
//! partial-distance early exit (`ops::sq_dist_pruned`) — bit-identical
//! to the naive path (`ops::argmin_n` ties break by index on both
//! sides), so which path runs is purely a perf decision.

use crate::tensor::ops;
use crate::util::rng::Rng;
use crate::util::threadpool::{SyncPtr, ThreadPool};

use super::codebook::Codebook;

/// Groups per scheduling chunk for the `(s, k)` distance sweep.  Fixed —
/// never derived from the worker count — so per-chunk RNG streams and
/// chunk-local writes give bit-identical output at every thread count.
const CHUNK: usize = 64;

/// Candidate-initialization strategy (Table 7).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AssignInit {
    /// Uniformly random codewords (Table 7 col 1 — the failure mode).
    Random,
    /// Top-n by cosine similarity (Table 7 col 2).
    Cosine,
    /// Top-n by Euclidean distance (Table 7 col 3 — the paper's choice).
    Euclid,
}

/// Candidate table + distances for `(s, d)` sub-vectors.
#[derive(Clone, Debug)]
pub struct Candidates {
    pub n: usize,
    /// `(s, n)` codeword indices, best first.
    pub assign: Vec<u32>,
    /// `(s, n)` squared distances (Euclid) or 1-cos (Cosine); random
    /// init stores Euclidean distances of the random picks.
    pub dist: Vec<f32>,
}

/// Build the candidate table (Eq. 5 generalized per Table 7) on the
/// serial path.  Identical, bit for bit, to [`candidates_with`] at any
/// thread count — both run the same chunked schedule.
pub fn candidates(
    flat: &[f32],
    cb: &Codebook,
    n: usize,
    init: AssignInit,
    rng: &mut Rng,
) -> Candidates {
    candidates_with(flat, cb, n, init, rng, None)
}

/// Build the candidate table, optionally spreading the `(s, k)` distance
/// sweep over a worker pool.  The RNG stream of each chunk is derived
/// from the chunk index (not from thread interleaving), so the result is
/// a pure function of `(flat, cb, n, init, rng seed)`.
pub fn candidates_with(
    flat: &[f32],
    cb: &Codebook,
    n: usize,
    init: AssignInit,
    rng: &mut Rng,
    pool: Option<&ThreadPool>,
) -> Candidates {
    assert_eq!(flat.len() % cb.d, 0);
    let s = flat.len() / cb.d;
    assert!(n >= 1 && n <= cb.k, "n={n} out of range for k={}", cb.k);
    let mut assign = vec![0u32; s * n];
    let mut dist = vec![0.0f32; s * n];
    // One base draw keys every chunk stream; the parent RNG advances by
    // exactly one step regardless of s or the thread count.
    let base = rng.next_u64();

    // §Perf: the Euclid sweep at d >= PRUNE_MIN_D keeps a running top-n
    // buffer and prunes each candidate with the partial-distance scan.
    // The buffer holds the n lexicographically-smallest (dist, index)
    // pairs seen so far — exactly what `ops::argmin_n` (index tie-break)
    // returns over the full scratch table — and the strict bail keeps
    // distance-equals-bound candidates alive, so the output is
    // bit-identical to the naive scratch path retained below (proven on
    // adversarial near-tie codebooks in `rust/tests/prop_substrate.rs`).
    let prune = init == AssignInit::Euclid && ops::prunes_at(cb.d);

    let kernel = |start: usize, end: usize, assign_chunk: &mut [u32], dist_chunk: &mut [f32]| {
        let mut crng = Rng::chunk_stream(base, start / CHUNK);
        let mut scratch = vec![0.0f32; cb.k];
        let mut top: Vec<(f32, u32)> = Vec::with_capacity(n);
        for g in start..end {
            let sub = &flat[g * cb.d..(g + 1) * cb.d];
            let row = (g - start) * n;
            match init {
                AssignInit::Random => {
                    for m in 0..n {
                        let c = crng.below(cb.k);
                        assign_chunk[row + m] = c as u32;
                        dist_chunk[row + m] = ops::sq_dist(sub, cb.word(c));
                    }
                }
                AssignInit::Euclid if prune => {
                    top.clear();
                    for c in 0..cb.k {
                        // Bail bound: the current n-th best (∞ until the
                        // buffer fills).  Scan order is index order, so a
                        // later candidate tying the worst entry never
                        // displaces it — insertion is strictly-less only.
                        let limit = if top.len() == n { top[n - 1].0 } else { f32::INFINITY };
                        let Some(dist) = ops::sq_dist_pruned(sub, cb.word(c), limit) else {
                            continue;
                        };
                        if top.len() == n {
                            if dist >= top[n - 1].0 {
                                continue;
                            }
                            top.pop();
                        }
                        let mut pos = top.len();
                        while pos > 0 && dist < top[pos - 1].0 {
                            pos -= 1;
                        }
                        top.insert(pos, (dist, c as u32));
                    }
                    for (m, &(dv, ci)) in top.iter().enumerate() {
                        assign_chunk[row + m] = ci;
                        dist_chunk[row + m] = dv;
                    }
                }
                AssignInit::Euclid | AssignInit::Cosine => {
                    for c in 0..cb.k {
                        scratch[c] = match init {
                            AssignInit::Euclid => ops::sq_dist(sub, cb.word(c)),
                            AssignInit::Cosine => 1.0 - ops::cosine(sub, cb.word(c)),
                            AssignInit::Random => unreachable!(),
                        };
                    }
                    for (m, &c) in ops::argmin_n(&scratch, n).iter().enumerate() {
                        assign_chunk[row + m] = c as u32;
                        dist_chunk[row + m] = scratch[c];
                    }
                }
            }
        }
    };

    match pool {
        Some(pool) if pool.threads() > 1 && s > CHUNK => {
            let assign_ptr = SyncPtr::new(&mut assign);
            let dist_ptr = SyncPtr::new(&mut dist);
            pool.parallel_for(s, CHUNK, |start, end| {
                // SAFETY: parallel_for chunks are disjoint group ranges,
                // so the [start*n, end*n) windows never overlap.
                let a = unsafe { assign_ptr.slice(start * n, (end - start) * n) };
                // SAFETY: same disjoint [start*n, end*n) windows, in the
                // separately-allocated distance buffer.
                let d = unsafe { dist_ptr.slice(start * n, (end - start) * n) };
                kernel(start, end, a, d);
            })
            .expect("candidate sweep worker panicked");
        }
        _ => {
            let mut start = 0;
            while start < s {
                let end = (start + CHUNK).min(s);
                let (a, d) = (
                    &mut assign[start * n..end * n],
                    &mut dist[start * n..end * n],
                );
                kernel(start, end, a, d);
                start = end;
            }
        }
    }
    Candidates { n, assign, dist }
}

/// Codeword-utilization summary of one assignment stream (arXiv
/// 2309.17361 motivates tracking this: dead codewords are wasted ROM,
/// and a collapsed assignment distribution signals a bad codebook or a
/// scale-mismatched net).  Computed from the final integer codes, so it
/// is exactly reproducible on any path that produced identical codes —
/// the staged encoder reports one per stage, and the serving shards
/// surface one per hosted net through the TCP `/stats` verb.
#[derive(Clone, Debug, PartialEq)]
pub struct Utilization {
    /// Codebook entries the stream could draw from (the stage's
    /// `stage_k` prefix, or the full `k`).
    pub k: usize,
    /// Assignments counted.
    pub total: usize,
    /// Codewords hit at least once.
    pub used: usize,
    /// Shannon entropy of the empirical assignment distribution, in
    /// bits — `log2(k)` at perfectly balanced usage, 0 at collapse.
    pub entropy_bits: f64,
}

impl Utilization {
    /// Histogram `codes` against a `k`-entry codebook.  Serial by
    /// design: one pass over the final codes, integer counts, and a
    /// f64 entropy accumulated in index order — deterministic without
    /// any scheduling contract.
    pub fn from_codes(codes: &[u32], k: usize) -> Self {
        assert!(k > 0, "utilization over an empty codebook");
        let mut counts = vec![0u64; k];
        for &c in codes {
            counts[c as usize] += 1;
        }
        Self::from_counts(&counts)
    }

    /// Summarize a pre-built histogram (`counts[c]` = assignments of
    /// codeword `c`) — the incremental path for callers that stream the
    /// codes in chunks, like shard hosting validation.
    pub fn from_counts(counts: &[u64]) -> Self {
        let k = counts.len();
        assert!(k > 0, "utilization over an empty codebook");
        let total: u64 = counts.iter().sum();
        let used = counts.iter().filter(|&&c| c > 0).count();
        let mut entropy_bits = 0.0f64;
        if total > 0 {
            for &c in counts {
                if c > 0 {
                    let p = c as f64 / total as f64;
                    entropy_bits -= p * p.log2();
                }
            }
        }
        Utilization { k, total: total as usize, used, entropy_bits }
    }

    /// Fraction of the codebook hit at least once.
    pub fn used_fraction(&self) -> f64 {
        self.used as f64 / self.k as f64
    }
}

/// Eq. 7: logits `z_m = ln(d_last / d_m)` so softmax(z) ∝ 1/d.
pub fn init_ratio_logits(cand: &Candidates) -> Vec<f32> {
    let n = cand.n;
    let s = cand.dist.len() / n;
    let mut z = vec![0.0f32; s * n];
    for g in 0..s {
        let row = &cand.dist[g * n..(g + 1) * n];
        let last = row[n - 1].max(1e-12);
        for m in 0..n {
            z[g * n + m] = (last / row[m].max(1e-12)).ln();
        }
    }
    z
}

/// Equal-initialization alternative (supplementary §10's comparison):
/// all logits zero -> uniform ratios.
pub fn equal_ratio_logits(s: usize, n: usize) -> Vec<f32> {
    vec![0.0; s * n]
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cb() -> Codebook {
        Codebook::new(4, 2, vec![0., 0., 1., 0., 0., 1., 5., 5.])
    }

    #[test]
    fn euclid_orders_by_distance() {
        let mut rng = Rng::new(1);
        let flat = [0.9f32, 0.1]; // nearest (1,0), then (0,0), then (0,1)
        let c = candidates(&flat, &cb(), 3, AssignInit::Euclid, &mut rng);
        assert_eq!(c.assign[0], 1);
        assert_eq!(c.assign[1], 0);
        assert_eq!(c.assign[2], 2);
        assert!(c.dist[0] <= c.dist[1] && c.dist[1] <= c.dist[2]);
    }

    #[test]
    fn cosine_differs_from_euclid_on_scaled_words() {
        // (5,5) has perfect cosine with (0.1,0.1) but large distance.
        let mut rng = Rng::new(2);
        let flat = [0.1f32, 0.1];
        let e = candidates(&flat, &cb(), 1, AssignInit::Euclid, &mut rng);
        let c = candidates(&flat, &cb(), 1, AssignInit::Cosine, &mut rng);
        assert_eq!(e.assign[0], 0, "euclid picks the origin");
        assert_eq!(c.assign[0], 3, "cosine picks the aligned word");
    }

    #[test]
    fn random_within_range_and_deterministic() {
        let mut rng = Rng::new(3);
        let flat = [0.0f32; 20];
        let a = candidates(&flat, &cb(), 4, AssignInit::Random, &mut rng);
        assert!(a.assign.iter().all(|&c| (c as usize) < 4));
        let mut rng2 = Rng::new(3);
        let b = candidates(&flat, &cb(), 4, AssignInit::Random, &mut rng2);
        assert_eq!(a.assign, b.assign);
    }

    #[test]
    fn ratio_logits_inverse_proportional() {
        let cand = Candidates {
            n: 3,
            assign: vec![0, 1, 2],
            dist: vec![0.5, 1.0, 2.0],
        };
        let z = init_ratio_logits(&cand);
        // softmax(z) proportional to 1/d: check r0/r1 = d1/d0 = 2.
        let e: Vec<f64> = z.iter().map(|&x| (x as f64).exp()).collect();
        assert!((e[0] / e[1] - 2.0).abs() < 1e-6);
        assert!((e[1] / e[2] - 2.0).abs() < 1e-6);
        assert!((z[2]).abs() < 1e-7, "last logit is 0 by construction");
    }

    /// The pruned Euclid top-n scan (d >= PRUNE_MIN_D) must equal the
    /// naive scratch + argmin_n reference bit for bit — duplicated
    /// codewords and planted exact matches force argmin tie-breaks.
    #[test]
    fn pruned_topn_matches_scratch_reference() {
        let mut rng = Rng::new(23);
        let d = 10; // >= ops::PRUNE_MIN_D
        let k = 24;
        let mut words = vec![0.0f32; k * d];
        rng.fill_normal(&mut words);
        let dup: Vec<f32> = words[2 * d..3 * d].to_vec();
        words[17 * d..18 * d].copy_from_slice(&dup); // exact duplicate pair
        let c = Codebook::new(k, d, words);
        let s = 120;
        let mut flat = vec![0.0f32; s * d];
        rng.fill_normal(&mut flat);
        let w2: Vec<f32> = c.word(2).to_vec();
        flat[7 * d..8 * d].copy_from_slice(&w2); // zero-distance tie
        for n in [1usize, 3, 8] {
            let mut r = Rng::new(5);
            let got = candidates(&flat, &c, n, AssignInit::Euclid, &mut r);
            for g in 0..s {
                let sub = &flat[g * d..(g + 1) * d];
                let scratch: Vec<f32> = (0..k).map(|cc| ops::sq_dist(sub, c.word(cc))).collect();
                for (m, &cc) in ops::argmin_n(&scratch, n).iter().enumerate() {
                    assert_eq!(got.assign[g * n + m], cc as u32, "n={n} g={g} m={m}");
                    assert_eq!(
                        got.dist[g * n + m].to_bits(),
                        scratch[cc].to_bits(),
                        "n={n} g={g} m={m} dist bits"
                    );
                }
            }
        }
    }

    #[test]
    fn parallel_sweep_is_bit_identical_to_serial() {
        let mut rng = Rng::new(9);
        let mut flat = vec![0.0f32; 2 * 500];
        rng.fill_normal(&mut flat);
        let pool = ThreadPool::new(4);
        for init in [AssignInit::Random, AssignInit::Cosine, AssignInit::Euclid] {
            let mut r1 = Rng::new(77);
            let mut r2 = Rng::new(77);
            let a = candidates(&flat, &cb(), 2, init, &mut r1);
            let b = candidates_with(&flat, &cb(), 2, init, &mut r2, Some(&pool));
            assert_eq!(a.assign, b.assign, "{init:?} assign diverged");
            assert_eq!(a.dist, b.dist, "{init:?} dist diverged");
        }
    }

    #[test]
    fn utilization_counts_used_and_entropy() {
        // 4 codes over k=8: words {0, 1, 3} used, 0 twice.
        let u = Utilization::from_codes(&[0, 1, 0, 3], 8);
        assert_eq!(u.k, 8);
        assert_eq!(u.total, 4);
        assert_eq!(u.used, 3);
        assert!((u.used_fraction() - 0.375).abs() < 1e-12);
        // p = [1/2, 1/4, 1/4] -> H = 1.5 bits.
        assert!((u.entropy_bits - 1.5).abs() < 1e-12, "{}", u.entropy_bits);

        let collapsed = Utilization::from_codes(&[5, 5, 5], 8);
        assert_eq!(collapsed.used, 1);
        assert_eq!(collapsed.entropy_bits, 0.0);

        let empty = Utilization::from_codes(&[], 8);
        assert_eq!(empty.used, 0);
        assert_eq!(empty.entropy_bits, 0.0);
    }

    #[test]
    fn n_bounds_checked() {
        let mut rng = Rng::new(4);
        let flat = [0.0f32, 0.0];
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            candidates(&flat, &cb(), 5, AssignInit::Euclid, &mut rng)
        }));
        assert!(res.is_err());
    }
}
