//! Codebook type + storage accounting (§3.1, Table 1's `C` column).

use crate::tensor::ops;

/// A `(k, d)` codebook of f32 codewords (row-major).
///
/// For the *universal* codebook this is frozen after KDE sampling (§4.1)
/// and conceptually lives in on-chip ROM; per-layer baselines create one
/// per layer (the `P-VQ` rows of Table 1).
#[derive(Clone, Debug, PartialEq)]
pub struct Codebook {
    pub k: usize,
    pub d: usize,
    pub words: Vec<f32>, // len = k * d
}

impl Codebook {
    pub fn new(k: usize, d: usize, words: Vec<f32>) -> Self {
        assert_eq!(words.len(), k * d, "codebook size mismatch");
        assert!(k > 0 && d > 0);
        Codebook { k, d, words }
    }

    pub fn word(&self, i: usize) -> &[f32] {
        &self.words[i * self.d..(i + 1) * self.d]
    }

    /// Storage cost in bytes at f32 (Table 1's `C` column).
    pub fn storage_bytes(&self) -> usize {
        self.k * self.d * 4
    }

    /// Assignment bits per weight: `log2(k) / d` (§3.1, the "ideal bit").
    pub fn bits_per_weight(&self) -> f64 {
        (self.k as f64).log2() / self.d as f64
    }

    /// Bits needed to store one assignment index.
    pub fn index_bits(&self) -> u32 {
        (usize::BITS - (self.k - 1).leading_zeros()).max(1)
    }

    /// Hard decode: `out[s] = words[codes[s]]` (Eq. 2).
    pub fn decode(&self, codes: &[u32], out: &mut [f32]) {
        assert_eq!(out.len(), codes.len() * self.d, "decode output size");
        for (s, &c) in codes.iter().enumerate() {
            let w = self.word(c as usize);
            out[s * self.d..(s + 1) * self.d].copy_from_slice(w);
        }
    }

    /// Decode into a fresh buffer.
    pub fn decode_vec(&self, codes: &[u32]) -> Vec<f32> {
        let mut out = vec![0.0; codes.len() * self.d];
        self.decode(codes, &mut out);
        out
    }

    /// Weighted decode `out[s] = sum_m r[s,m] * words[assign[s,m]]`
    /// (Eq. 8) — host-side mirror of the Pallas reconstruct kernel,
    /// used by the coordinator's checkpoint validation.
    pub fn decode_weighted(&self, assign: &[u32], ratios: &[f32], n: usize, out: &mut [f32]) {
        let s = assign.len() / n;
        assert_eq!(assign.len(), s * n);
        assert_eq!(ratios.len(), s * n);
        assert_eq!(out.len(), s * self.d);
        out.fill(0.0);
        for g in 0..s {
            let orow = &mut out[g * self.d..(g + 1) * self.d];
            for m in 0..n {
                let r = ratios[g * n + m];
                if r == 0.0 {
                    continue;
                }
                let w = self.word(assign[g * n + m] as usize);
                for j in 0..self.d {
                    orow[j] += r * w[j];
                }
            }
        }
    }

    /// Quantization MSE of encoding `flat` (S*d) with nearest codewords.
    /// Returns (mse, codes).  This is Table 1's `MSE` column.
    pub fn encode_nearest(&self, flat: &[f32]) -> (f64, Vec<u32>) {
        assert_eq!(flat.len() % self.d, 0);
        let s = flat.len() / self.d;
        let mut codes = vec![0u32; s];
        let mut err = 0.0f64;
        for g in 0..s {
            let sub = &flat[g * self.d..(g + 1) * self.d];
            let mut best = 0usize;
            let mut best_d = f32::INFINITY;
            for c in 0..self.k {
                let dist = ops::sq_dist(sub, self.word(c));
                if dist < best_d {
                    best_d = dist;
                    best = c;
                }
            }
            codes[g] = best as u32;
            err += best_d as f64;
        }
        (err / flat.len() as f64, codes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cb() -> Codebook {
        Codebook::new(4, 2, vec![0., 0., 1., 0., 0., 1., 1., 1.])
    }

    #[test]
    fn decode_roundtrip() {
        let c = cb();
        let codes = [3u32, 0, 1];
        let out = c.decode_vec(&codes);
        assert_eq!(out, vec![1., 1., 0., 0., 1., 0.]);
    }

    #[test]
    fn encode_nearest_exact_on_codewords() {
        let c = cb();
        let flat = [1.0f32, 1.0, 0.0, 1.0];
        let (mse, codes) = c.encode_nearest(&flat);
        assert_eq!(codes, vec![3, 2]);
        assert_eq!(mse, 0.0);
    }

    #[test]
    fn encode_nearest_error_value() {
        let c = cb();
        // (0.5, 0.0) is 0.25 away (sq) from both (0,0) and (1,0).
        let (mse, _) = c.encode_nearest(&[0.5, 0.0]);
        assert!((mse - 0.125).abs() < 1e-7, "0.25 sq err over 2 weights");
    }

    #[test]
    fn weighted_decode_matches_hard_at_onehot() {
        let c = cb();
        let assign = [0u32, 3, 1, 2]; // 2 groups, n=2
        let ratios = [1.0f32, 0.0, 0.0, 1.0];
        let mut out = vec![0.0; 4];
        c.decode_weighted(&assign, &ratios, 2, &mut out);
        assert_eq!(out, vec![0., 0., 0., 1.]);
    }

    #[test]
    fn weighted_decode_mixes() {
        let c = cb();
        let assign = [1u32, 2]; // one group, n=2: (1,0) and (0,1)
        let ratios = [0.5f32, 0.5];
        let mut out = vec![0.0; 2];
        c.decode_weighted(&assign, &ratios, 2, &mut out);
        assert_eq!(out, vec![0.5, 0.5]);
    }

    #[test]
    fn storage_and_bits() {
        let c = Codebook::new(256, 4, vec![0.0; 1024]);
        assert_eq!(c.storage_bytes(), 4096);
        assert_eq!(c.bits_per_weight(), 2.0);
        assert_eq!(c.index_bits(), 8);
        let c2 = Codebook::new(65536, 8, vec![0.0; 65536 * 8]);
        assert_eq!(c2.bits_per_weight(), 2.0);
        assert_eq!(c2.index_bits(), 16);
    }
}
