//! Codebook type + storage accounting (§3.1, Table 1's `C` column).
//!
//! The decode/encode sweeps here are serving-path hot loops (§3.2: the
//! packed assignment stream is decoded on the fly at inference time), so
//! they run over the same fixed-chunk deterministic schedule as the
//! construction hot paths: chunk boundaries depend only on the input
//! size, per-chunk float partials reduce in chunk order, and the pooled
//! paths are bit-identical to serial at every thread count
//! (property-tested in `rust/tests/prop_substrate.rs`).
//!
//! §Perf (specialized kernels): the fused streaming decode
//! ([`Codebook::decode_packed_into`]) runs the word-level
//! `vq::pack::unpack_range` and a small-`d` (1..=4) monomorphized
//! gather — or, at `d >= vq::simd::LANES`, the runtime-dispatched SIMD
//! gather (`vq::simd::gather_rows`, AVX2/NEON/scalar); the
//! nearest-codeword encode runs the norm-seeded partial-distance pruned
//! scan (`tensor::ops::nearest_pruned`, itself lane-order SIMD at those
//! widths) at `ops::prunes_at(d)`.  Both keep their scalar originals —
//! [`Codebook::decode_packed_into_reference`] and
//! [`Codebook::encode_nearest_reference`] — as property-test ground
//! truth and as the legacy side of the `fused_decode` / `encode_pruned`
//! hotpath bench rows.  Note `Codebook::decode` / `decode_vec` already
//! ride the same gather core (`decode_with`'s chunk kernel *is*
//! [`Codebook::gather`]), so there is exactly one decode kernel family.
//!
//! §Residual stages: [`Codebook::encode_staged`] quantizes residuals
//! against successive *prefixes of the same codebook* (stage `s` scans
//! the first `2^bits_s` codewords — pure index restriction, no extra
//! ROM), and [`Codebook::decode_staged_packed_into`] reconstructs as a
//! sum of per-stage gathers (stage 0 writes, stages >= 1 accumulate).
//! Both keep scalar originals — [`Codebook::encode_staged_reference`]
//! and [`Codebook::decode_staged_packed_into_reference`] — as the
//! ground truth and legacy sides of the `staged_encode` /
//! `staged_decode` bench rows.

use crate::tensor::ops;
use crate::util::threadpool::{SyncPtr, ThreadPool};
use crate::vq::assign::Utilization;
use crate::vq::simd;
use crate::vq::pack::{
    pack_codes, pack_codes_reference, unpack_range, unpack_range_reference, PackedCodes,
    StagedCodes,
};

/// Groups per scheduling chunk for the encode/decode sweeps.  Fixed —
/// never derived from the worker count — so the error-partial grouping
/// is identical at every parallelism setting.
const CHUNK: usize = 128;

/// A `(k, d)` codebook of f32 codewords (row-major).
///
/// For the *universal* codebook this is frozen after KDE sampling (§4.1)
/// and conceptually lives in on-chip ROM; per-layer baselines create one
/// per layer (the `P-VQ` rows of Table 1).
#[derive(Clone, Debug, PartialEq)]
pub struct Codebook {
    pub k: usize,
    pub d: usize,
    pub words: Vec<f32>, // len = k * d
    /// Per-codeword squared norms, computed once at construction — the
    /// seed input of the pruned nearest-codeword scan (§Perf).  Derived
    /// from `words`, so it never goes stale: the only construction site
    /// is [`Codebook::new`] and `words` is never mutated in place.
    norms: Vec<f32>, // len = k
}

impl Codebook {
    pub fn new(k: usize, d: usize, words: Vec<f32>) -> Self {
        assert_eq!(words.len(), k * d, "codebook size mismatch");
        assert!(k > 0 && d > 0);
        let norms = words.chunks_exact(d).map(|w| ops::dot(w, w)).collect();
        Codebook { k, d, words, norms }
    }

    pub fn word(&self, i: usize) -> &[f32] {
        &self.words[i * self.d..(i + 1) * self.d]
    }

    /// Precomputed squared norm of each codeword (len `k`).
    pub fn norms(&self) -> &[f32] {
        &self.norms
    }

    /// Storage cost in bytes at f32 (Table 1's `C` column).
    pub fn storage_bytes(&self) -> usize {
        self.k * self.d * 4
    }

    /// Assignment bits per weight: `log2(k) / d` (§3.1, the "ideal bit").
    pub fn bits_per_weight(&self) -> f64 {
        (self.k as f64).log2() / self.d as f64
    }

    /// Bits needed to store one assignment index.
    pub fn index_bits(&self) -> u32 {
        (usize::BITS - (self.k - 1).leading_zeros()).max(1)
    }

    /// Hard decode: `out[s] = words[codes[s]]` (Eq. 2).  Serial entry
    /// point — identical output to [`Codebook::decode_with`] at any
    /// thread count.
    pub fn decode(&self, codes: &[u32], out: &mut [f32]) {
        self.decode_with(codes, out, None)
    }

    /// Hard decode with the codeword copies spread over fixed chunks of
    /// codes.  Each chunk writes a disjoint output window, so the result
    /// is trivially identical to the serial path.
    pub fn decode_with(&self, codes: &[u32], out: &mut [f32], pool: Option<&ThreadPool>) {
        assert_eq!(out.len(), codes.len() * self.d, "decode output size");
        let s = codes.len();

        let kernel =
            |start: usize, end: usize, dst: &mut [f32]| self.gather(&codes[start..end], dst);

        match pool {
            Some(pool) if pool.threads() > 1 && s > CHUNK => {
                let out_ptr = SyncPtr::new(out);
                pool.note_read(codes);
                pool.note_read(&self.words);
                pool.parallel_for(s, CHUNK, |start, end| {
                    // SAFETY: parallel_for chunks are disjoint code ranges,
                    // so the output windows never overlap.
                    let dst = unsafe { out_ptr.slice(start * self.d, (end - start) * self.d) };
                    kernel(start, end, dst);
                })
                .expect("decode worker panicked");
            }
            _ => {
                let mut start = 0;
                while start < s {
                    let end = (start + CHUNK).min(s);
                    kernel(start, end, &mut out[start * self.d..end * self.d]);
                    start = end;
                }
            }
        }
    }

    /// The gather half of every decode: `dst[i] = words[codes[i]]`, with
    /// dedicated small-`d` (1..=4) kernels that move a compile-time-sized
    /// row instead of calling `copy_from_slice` with a runtime length,
    /// and the runtime-dispatched SIMD row copy at `d >= simd::LANES`
    /// (probed per call — one acquire-load per 128-code chunk) — pure
    /// copies on every arm, so the output is bit-identical to the
    /// generic path.
    fn gather(&self, codes: &[u32], dst: &mut [f32]) {
        debug_assert_eq!(dst.len(), codes.len() * self.d);
        match self.d {
            1 => {
                for (slot, &c) in dst.iter_mut().zip(codes) {
                    *slot = self.words[c as usize];
                }
            }
            2 => gather_fixed::<2>(&self.words, codes, dst),
            3 => gather_fixed::<3>(&self.words, codes, dst),
            4 => gather_fixed::<4>(&self.words, codes, dst),
            d if d >= simd::LANES => {
                simd::gather_rows(simd::active(), &self.words, codes, d, dst)
            }
            d => {
                for (row, &c) in dst.chunks_exact_mut(d).zip(codes) {
                    row.copy_from_slice(&self.words[c as usize * d..(c as usize + 1) * d]);
                }
            }
        }
    }

    /// Fused unpack + decode of the packed code window `[start, end)`
    /// straight into `out` (`out.len() == (end - start) * d`) — the
    /// serving engine's streaming path (cache-miss decode and
    /// `stream_batch` both land here): no intermediate codes vector, no
    /// weights allocation.  Each stack-buffered chunk runs the word-level
    /// [`unpack_range`] and then the small-`d`-specialized gather; both
    /// stages are pure copies, so the output is bit-identical to
    /// `unpack_range` followed by [`Codebook::decode`] — and to the
    /// retained [`Codebook::decode_packed_into_reference`].
    pub fn decode_packed_into(&self, p: &PackedCodes, start: usize, end: usize, out: &mut [f32]) {
        assert!(
            start <= end && end <= p.count,
            "window [{start}, {end}) out of the {}-code stream",
            p.count
        );
        assert_eq!(out.len(), (end - start) * self.d, "decode_packed_into output size");
        const FUSE_CHUNK: usize = 128;
        let mut buf = [0u32; FUSE_CHUNK];
        let mut s = start;
        while s < end {
            let e = (s + FUSE_CHUNK).min(end);
            let codes = &mut buf[..e - s];
            unpack_range(p, s, e, codes);
            self.gather(codes, &mut out[(s - start) * self.d..(e - start) * self.d]);
            s = e;
        }
    }

    /// The retained scalar reference for [`Codebook::decode_packed_into`]:
    /// bit-at-a-time unpack ([`unpack_range_reference`]) and the generic
    /// per-code `copy_from_slice` — the property-test ground truth and
    /// the legacy side of the `fused_decode` hotpath bench row.
    pub fn decode_packed_into_reference(
        &self,
        p: &PackedCodes,
        start: usize,
        end: usize,
        out: &mut [f32],
    ) {
        assert!(
            start <= end && end <= p.count,
            "window [{start}, {end}) out of the {}-code stream",
            p.count
        );
        assert_eq!(out.len(), (end - start) * self.d, "decode_packed_into output size");
        const FUSE_CHUNK: usize = 128;
        let mut buf = [0u32; FUSE_CHUNK];
        let mut s = start;
        while s < end {
            let e = (s + FUSE_CHUNK).min(end);
            let codes = &mut buf[..e - s];
            unpack_range_reference(p, s, e, codes);
            for (off, &c) in codes.iter().enumerate() {
                let o = (s - start + off) * self.d;
                out[o..o + self.d].copy_from_slice(self.word(c as usize));
            }
            s = e;
        }
    }

    /// The accumulate twin of [`Codebook::gather`] for residual stages:
    /// `dst[i] += words[codes[i]]`, with the same small-`d` (1..=4)
    /// monomorphized kernels and the SIMD accumulate at
    /// `d >= simd::LANES`.  Element adds stay independent per element
    /// (lane-wise vector adds are exactly one f32 add each), so the
    /// staged sum is bit-identical to the reference accumulation.
    fn gather_add(&self, codes: &[u32], dst: &mut [f32]) {
        debug_assert_eq!(dst.len(), codes.len() * self.d);
        match self.d {
            1 => {
                for (slot, &c) in dst.iter_mut().zip(codes) {
                    *slot += self.words[c as usize];
                }
            }
            2 => gather_add_fixed::<2>(&self.words, codes, dst),
            3 => gather_add_fixed::<3>(&self.words, codes, dst),
            4 => gather_add_fixed::<4>(&self.words, codes, dst),
            d if d >= simd::LANES => {
                simd::gather_rows_add(simd::active(), &self.words, codes, d, dst)
            }
            d => {
                for (row, &c) in dst.chunks_exact_mut(d).zip(codes) {
                    let w = &self.words[c as usize * d..(c as usize + 1) * d];
                    for (slot, wj) in row.iter_mut().zip(w) {
                        *slot += wj;
                    }
                }
            }
        }
    }

    /// Fused staged decode of row window `[start, end)`: stage 0 runs
    /// the existing fused unpack + gather *write*
    /// ([`Codebook::decode_packed_into`]), every later stage runs the
    /// same word-level unpack and a gather *accumulate* — a sum of one
    /// gather per stage, no intermediate codes or weights allocation.
    /// At `stages == 1` this is exactly the legacy fused decode.  The
    /// serving engine's cache-miss and `stream_batch` paths land here.
    /// Bit-identical to the retained
    /// [`Codebook::decode_staged_packed_into_reference`] (same
    /// stage-major add order per element).
    pub fn decode_staged_packed_into(
        &self,
        staged: &StagedCodes,
        start: usize,
        end: usize,
        out: &mut [f32],
    ) {
        self.decode_packed_into(staged.stage(0), start, end, out);
        const FUSE_CHUNK: usize = 128;
        let mut buf = [0u32; FUSE_CHUNK];
        for stage in 1..staged.stages() {
            let p = staged.stage(stage);
            let mut s = start;
            while s < end {
                let e = (s + FUSE_CHUNK).min(end);
                let codes = &mut buf[..e - s];
                unpack_range(p, s, e, codes);
                self.gather_add(codes, &mut out[(s - start) * self.d..(e - start) * self.d]);
                s = e;
            }
        }
    }

    /// The retained scalar reference for
    /// [`Codebook::decode_staged_packed_into`]: bit-at-a-time unpack
    /// ([`unpack_range_reference`]) and per-code scalar write/add loops,
    /// stage-major like the fused path — the property-test ground truth
    /// and the legacy side of the `staged_decode` hotpath bench row.
    pub fn decode_staged_packed_into_reference(
        &self,
        staged: &StagedCodes,
        start: usize,
        end: usize,
        out: &mut [f32],
    ) {
        self.decode_packed_into_reference(staged.stage(0), start, end, out);
        const FUSE_CHUNK: usize = 128;
        let mut buf = [0u32; FUSE_CHUNK];
        for stage in 1..staged.stages() {
            let p = staged.stage(stage);
            let mut s = start;
            while s < end {
                let e = (s + FUSE_CHUNK).min(end);
                let codes = &mut buf[..e - s];
                unpack_range_reference(p, s, e, codes);
                for (off, &c) in codes.iter().enumerate() {
                    let o = (s - start + off) * self.d;
                    let w = self.word(c as usize);
                    for (slot, wj) in out[o..o + self.d].iter_mut().zip(w) {
                        *slot += wj;
                    }
                }
                s = e;
            }
        }
    }

    /// Decode into a fresh buffer.
    pub fn decode_vec(&self, codes: &[u32]) -> Vec<f32> {
        let mut out = vec![0.0; codes.len() * self.d];
        self.decode(codes, &mut out);
        out
    }

    /// Weighted decode `out[s] = sum_m r[s,m] * words[assign[s,m]]`
    /// (Eq. 8) — host-side mirror of the Pallas reconstruct kernel,
    /// used by the coordinator's checkpoint validation.  Serial entry
    /// point — identical output to [`Codebook::decode_weighted_with`].
    pub fn decode_weighted(&self, assign: &[u32], ratios: &[f32], n: usize, out: &mut [f32]) {
        self.decode_weighted_with(assign, ratios, n, out, None)
    }

    /// Weighted decode over fixed chunks of groups.  Each group's row is
    /// accumulated independently (candidate order within the row never
    /// changes), so the pooled path is bit-identical to serial.
    pub fn decode_weighted_with(
        &self,
        assign: &[u32],
        ratios: &[f32],
        n: usize,
        out: &mut [f32],
        pool: Option<&ThreadPool>,
    ) {
        let s = assign.len() / n;
        assert_eq!(assign.len(), s * n);
        assert_eq!(ratios.len(), s * n);
        assert_eq!(out.len(), s * self.d);

        let kernel = |start: usize, end: usize, dst: &mut [f32]| {
            dst.fill(0.0);
            for g in start..end {
                let orow = &mut dst[(g - start) * self.d..(g - start + 1) * self.d];
                for m in 0..n {
                    let r = ratios[g * n + m];
                    if r == 0.0 {
                        continue;
                    }
                    let w = self.word(assign[g * n + m] as usize);
                    for j in 0..self.d {
                        orow[j] += r * w[j];
                    }
                }
            }
        };

        match pool {
            Some(pool) if pool.threads() > 1 && s > CHUNK => {
                let out_ptr = SyncPtr::new(out);
                pool.note_read(assign);
                pool.note_read(ratios);
                pool.note_read(&self.words);
                pool.parallel_for(s, CHUNK, |start, end| {
                    // SAFETY: disjoint group windows per chunk.
                    let dst = unsafe { out_ptr.slice(start * self.d, (end - start) * self.d) };
                    kernel(start, end, dst);
                })
                .expect("weighted decode worker panicked");
            }
            _ => {
                let mut start = 0;
                while start < s {
                    let end = (start + CHUNK).min(s);
                    kernel(start, end, &mut out[start * self.d..end * self.d]);
                    start = end;
                }
            }
        }
    }

    /// Quantization MSE of encoding `flat` (S*d) with nearest codewords.
    /// Returns (mse, codes).  This is Table 1's `MSE` column.  Serial
    /// entry point — identical output to
    /// [`Codebook::encode_nearest_with`] at any thread count.
    pub fn encode_nearest(&self, flat: &[f32]) -> (f64, Vec<u32>) {
        self.encode_nearest_with(flat, None)
    }

    /// Nearest-codeword encode with the `(s, k)` distance sweep spread
    /// over fixed chunks of groups.  Each chunk writes a disjoint codes
    /// range and its own error-partial slot; the partials reduce in
    /// chunk order, so the f64 MSE is bit-identical at every thread
    /// count (both paths run the same chunked schedule).
    ///
    /// §Perf (pruned scan): at `d >= ops::PRUNE_MIN_D` the inner scan
    /// runs [`ops::nearest_pruned`] — norm-seeded bound plus
    /// partial-distance early exit — which is proven bit-identical
    /// (codes, argmin tie-breaks, the f32 distance bits that feed the
    /// f64 MSE partials) to the naive scan retained in
    /// [`Codebook::encode_nearest_reference`]; smaller `d` keeps the
    /// naive scan, where bail checks cost more than they save.
    pub fn encode_nearest_with(&self, flat: &[f32], pool: Option<&ThreadPool>) -> (f64, Vec<u32>) {
        assert_eq!(flat.len() % self.d, 0);
        let s = flat.len() / self.d;
        let mut codes = vec![0u32; s];
        if s == 0 {
            return (0.0, codes);
        }
        let nchunks = s.div_ceil(CHUNK);
        let mut errs = vec![0.0f64; nchunks];
        let prune = ops::prunes_at(self.d);

        let kernel = |start: usize, end: usize, codes_chunk: &mut [u32]| -> f64 {
            let mut local = 0.0f64;
            for (off, code) in codes_chunk.iter_mut().enumerate() {
                let g = start + off;
                let sub = &flat[g * self.d..(g + 1) * self.d];
                let (best, best_d) = if prune {
                    ops::nearest_pruned(sub, &self.words, &self.norms)
                } else {
                    let mut best = 0usize;
                    let mut best_d = f32::INFINITY;
                    for c in 0..self.k {
                        let dist = ops::sq_dist(sub, self.word(c));
                        if dist < best_d {
                            best_d = dist;
                            best = c;
                        }
                    }
                    (best, best_d)
                };
                *code = best as u32;
                local += best_d as f64;
            }
            local
        };

        match pool {
            Some(pool) if pool.threads() > 1 && s > CHUNK => {
                let codes_ptr = SyncPtr::new(&mut codes);
                let errs_ptr = SyncPtr::new(&mut errs);
                pool.note_read(flat);
                pool.note_read(&self.words);
                pool.parallel_for(s, CHUNK, |start, end| {
                    // SAFETY: parallel_for ranges are disjoint.
                    let chunk = unsafe { codes_ptr.slice(start, end - start) };
                    let e = kernel(start, end, chunk);
                    // SAFETY: each chunk index maps to a unique error slot.
                    unsafe { errs_ptr.slice(start / CHUNK, 1)[0] = e };
                })
                .expect("encode_nearest worker panicked");
            }
            _ => {
                let mut start = 0;
                while start < s {
                    let end = (start + CHUNK).min(s);
                    errs[start / CHUNK] = kernel(start, end, &mut codes[start..end]);
                    start = end;
                }
            }
        }
        let total: f64 = errs.iter().sum();
        (total / flat.len() as f64, codes)
    }

    /// The retained brute-force reference for
    /// [`Codebook::encode_nearest_with`]: the full `O(s*k*d)` scan with
    /// no pruning, over the identical serial chunk schedule (same CHUNK
    /// grouping, f64 partials summed in chunk order) — so `(mse, codes)`
    /// must match the pruned path bit for bit.  Property-tested against
    /// adversarial near-tie codebooks in `rust/tests/prop_substrate.rs`
    /// and benched as the legacy side of the `encode_pruned` row.
    pub fn encode_nearest_reference(&self, flat: &[f32]) -> (f64, Vec<u32>) {
        assert_eq!(flat.len() % self.d, 0);
        let s = flat.len() / self.d;
        let mut codes = vec![0u32; s];
        if s == 0 {
            return (0.0, codes);
        }
        let nchunks = s.div_ceil(CHUNK);
        let mut errs = vec![0.0f64; nchunks];
        let mut start = 0;
        while start < s {
            let end = (start + CHUNK).min(s);
            let mut local = 0.0f64;
            for (off, code) in codes[start..end].iter_mut().enumerate() {
                let g = start + off;
                let sub = &flat[g * self.d..(g + 1) * self.d];
                let mut best = 0usize;
                let mut best_d = f32::INFINITY;
                for c in 0..self.k {
                    let dist = ops::sq_dist(sub, self.word(c));
                    if dist < best_d {
                        best_d = dist;
                        best = c;
                    }
                }
                *code = best as u32;
                local += best_d as f64;
            }
            errs[start / CHUNK] = local;
            start = end;
        }
        let total: f64 = errs.iter().sum();
        (total / flat.len() as f64, codes)
    }

    /// Codewords a `bits`-wide stage may draw from: the first
    /// `min(2^bits, k)` entries of the one universal codebook — a pure
    /// index-prefix restriction, so matched-total-bit stage splits
    /// (e.g. 5+5 vs one 10-bit stage) share the exact same ROM as the
    /// full-width single stage.
    pub fn stage_k(&self, bits: u32) -> usize {
        assert!((1..=32).contains(&bits), "bits must be 1..=32");
        if bits >= usize::BITS || (1usize << bits) >= self.k {
            self.k
        } else {
            1usize << bits
        }
    }

    /// Residual multi-stage encode (arXiv 1907.05686 on the universal
    /// codebook): stage 0 is the nearest-codeword assignment of `flat`,
    /// stage `s` the nearest-codeword assignment of the residual left
    /// by stages `0..s` — each stage restricted to its
    /// [`Codebook::stage_k`] prefix and scanned with the same pruned
    /// kernel as [`Codebook::encode_nearest_with`] (at
    /// `d >= ops::PRUNE_MIN_D`).  Returns the packed per-stage streams
    /// plus per-stage MSE and codeword-utilization accounting.
    ///
    /// Determinism: the per-stage sweep runs the fixed-CHUNK schedule —
    /// disjoint codes/residual windows per chunk, f64 error partials
    /// summed in chunk order — so the pooled path is bit-identical to
    /// serial at every thread count, and both are bit-identical to the
    /// retained [`Codebook::encode_staged_reference`] (the pruned scan
    /// is distance-bit-exact vs the naive scan; the word-level pack is
    /// byte-exact vs the bit-loop pack).
    pub fn encode_staged(
        &self,
        flat: &[f32],
        stage_bits: &[u32],
        pool: Option<&ThreadPool>,
    ) -> StagedEncode {
        assert!(!stage_bits.is_empty(), "encode_staged needs at least one stage");
        assert_eq!(flat.len() % self.d, 0);
        let s = flat.len() / self.d;
        let mut residual = flat.to_vec();
        let mut streams = Vec::with_capacity(stage_bits.len());
        let mut stage_mse = Vec::with_capacity(stage_bits.len());
        let mut utilization = Vec::with_capacity(stage_bits.len());
        for &bits in stage_bits {
            let stage_k = self.stage_k(bits);
            let mut codes = vec![0u32; s];
            let err = self.encode_stage_with(&mut residual, stage_k, &mut codes, pool);
            stage_mse.push(err / flat.len().max(1) as f64);
            utilization.push(Utilization::from_codes(&codes, stage_k));
            streams.push(pack_codes(&codes, bits));
        }
        StagedEncode {
            mse: *stage_mse.last().expect("at least one stage"),
            codes: StagedCodes::new(streams),
            stage_mse,
            utilization,
        }
    }

    /// One residual stage: assign each group of `residual` to its
    /// nearest codeword among the first `stage_k`, subtract the chosen
    /// word in place, and return the summed squared error (the f32
    /// nearest distance accumulated into f64 chunk partials).
    fn encode_stage_with(
        &self,
        residual: &mut [f32],
        stage_k: usize,
        codes: &mut [u32],
        pool: Option<&ThreadPool>,
    ) -> f64 {
        let s = codes.len();
        debug_assert_eq!(residual.len(), s * self.d);
        if s == 0 {
            return 0.0;
        }
        let nchunks = s.div_ceil(CHUNK);
        let mut errs = vec![0.0f64; nchunks];
        let prune = ops::prunes_at(self.d);
        let words = &self.words[..stage_k * self.d];
        let norms = &self.norms[..stage_k];

        let kernel = |codes_chunk: &mut [u32], res_chunk: &mut [f32]| -> f64 {
            let mut local = 0.0f64;
            for (off, code) in codes_chunk.iter_mut().enumerate() {
                let sub = &mut res_chunk[off * self.d..(off + 1) * self.d];
                let (best, best_d) = if prune {
                    ops::nearest_pruned(sub, words, norms)
                } else {
                    let mut best = 0usize;
                    let mut best_d = f32::INFINITY;
                    for c in 0..stage_k {
                        let dist = ops::sq_dist(sub, &words[c * self.d..(c + 1) * self.d]);
                        if dist < best_d {
                            best_d = dist;
                            best = c;
                        }
                    }
                    (best, best_d)
                };
                *code = best as u32;
                let w = &words[best * self.d..(best + 1) * self.d];
                for (r, wj) in sub.iter_mut().zip(w) {
                    *r -= wj;
                }
                local += best_d as f64;
            }
            local
        };

        match pool {
            Some(pool) if pool.threads() > 1 && s > CHUNK => {
                let codes_ptr = SyncPtr::new(codes);
                let res_ptr = SyncPtr::new(residual);
                let errs_ptr = SyncPtr::new(&mut errs);
                pool.note_read(&self.words);
                pool.parallel_for(s, CHUNK, |start, end| {
                    // SAFETY: parallel_for ranges are disjoint group
                    // ranges, so the codes and residual windows never
                    // overlap across chunks.
                    let chunk = unsafe { codes_ptr.slice(start, end - start) };
                    let res = unsafe { res_ptr.slice(start * self.d, (end - start) * self.d) };
                    let e = kernel(chunk, res);
                    // SAFETY: each chunk index maps to a unique error slot.
                    unsafe { errs_ptr.slice(start / CHUNK, 1)[0] = e };
                })
                .expect("encode_staged worker panicked");
            }
            _ => {
                let mut start = 0;
                while start < s {
                    let end = (start + CHUNK).min(s);
                    errs[start / CHUNK] = kernel(
                        &mut codes[start..end],
                        &mut residual[start * self.d..end * self.d],
                    );
                    start = end;
                }
            }
        }
        errs.iter().sum()
    }

    /// The retained brute-force reference for
    /// [`Codebook::encode_staged`]: per stage, the full naive scan over
    /// the `stage_k` prefix on the identical serial chunk schedule
    /// (same CHUNK grouping, f64 partials in chunk order, same in-place
    /// residual subtraction) and the bit-loop
    /// [`pack_codes_reference`] — so the whole [`StagedEncode`] (codes
    /// bytes, MSE bits, utilization) must match the specialized path
    /// exactly.  Property-tested in `rust/tests/prop_substrate.rs` and
    /// benched as the legacy side of the `staged_encode` row.
    pub fn encode_staged_reference(&self, flat: &[f32], stage_bits: &[u32]) -> StagedEncode {
        assert!(!stage_bits.is_empty(), "encode_staged needs at least one stage");
        assert_eq!(flat.len() % self.d, 0);
        let s = flat.len() / self.d;
        let mut residual = flat.to_vec();
        let mut streams = Vec::with_capacity(stage_bits.len());
        let mut stage_mse = Vec::with_capacity(stage_bits.len());
        let mut utilization = Vec::with_capacity(stage_bits.len());
        for &bits in stage_bits {
            let stage_k = self.stage_k(bits);
            let mut codes = vec![0u32; s];
            let nchunks = s.div_ceil(CHUNK);
            let mut errs = vec![0.0f64; nchunks];
            let mut start = 0;
            while start < s {
                let end = (start + CHUNK).min(s);
                let mut local = 0.0f64;
                for g in start..end {
                    let sub = &mut residual[g * self.d..(g + 1) * self.d];
                    let mut best = 0usize;
                    let mut best_d = f32::INFINITY;
                    for c in 0..stage_k {
                        let dist = ops::sq_dist(sub, &self.words[c * self.d..(c + 1) * self.d]);
                        if dist < best_d {
                            best_d = dist;
                            best = c;
                        }
                    }
                    codes[g] = best as u32;
                    let w = &self.words[best * self.d..(best + 1) * self.d];
                    for (r, wj) in sub.iter_mut().zip(w) {
                        *r -= wj;
                    }
                    local += best_d as f64;
                }
                errs[start / CHUNK] = local;
                start = end;
            }
            let err: f64 = errs.iter().sum();
            stage_mse.push(err / flat.len().max(1) as f64);
            utilization.push(Utilization::from_codes(&codes, stage_k));
            streams.push(pack_codes_reference(&codes, bits));
        }
        StagedEncode {
            mse: *stage_mse.last().expect("at least one stage"),
            codes: StagedCodes::new(streams),
            stage_mse,
            utilization,
        }
    }
}

/// Result of a staged (residual) encode: the packed per-stage streams
/// plus the accuracy and codeword-utilization accounting reported by
/// the stages sweep (`exp::stages`) and `compress_zoo`.
#[derive(Clone, Debug)]
pub struct StagedEncode {
    /// Per-stage packed assignment streams.
    pub codes: StagedCodes,
    /// Final reconstruction MSE after all stages (== last `stage_mse`).
    pub mse: f64,
    /// Residual MSE after each stage is applied.  (Not guaranteed
    /// monotone in general — a stage whose nearest codeword overshoots
    /// the residual can grow it — but non-increasing whenever the
    /// codebook contains a near-zero word, which KDE pools always do.)
    pub stage_mse: Vec<f64>,
    /// Per-stage codeword utilization over that stage's `stage_k`
    /// prefix (arXiv 2309.17361 motivates tracking this at all).
    pub utilization: Vec<Utilization>,
}

/// Monomorphized fixed-width row copy for the small-`d` gather: the
/// compiler moves `D` f32s with unrolled loads/stores instead of a
/// runtime-length `memcpy` call per code.
#[inline]
fn gather_fixed<const D: usize>(words: &[f32], codes: &[u32], dst: &mut [f32]) {
    for (row, &c) in dst.chunks_exact_mut(D).zip(codes) {
        let base = c as usize * D;
        let w: &[f32; D] = words[base..base + D].try_into().expect("codeword window");
        let row: &mut [f32; D] = row.try_into().expect("gather output row");
        *row = *w;
    }
}

/// The accumulate twin of [`gather_fixed`] for residual stages:
/// `dst_row += words[code]` with a compile-time-sized add loop.  The
/// element adds run in `j` order, exactly like the generic scalar loop,
/// so the staged sum stays bit-identical to the reference path.
#[inline]
fn gather_add_fixed<const D: usize>(words: &[f32], codes: &[u32], dst: &mut [f32]) {
    for (row, &c) in dst.chunks_exact_mut(D).zip(codes) {
        let base = c as usize * D;
        let w: &[f32; D] = words[base..base + D].try_into().expect("codeword window");
        let row: &mut [f32; D] = row.try_into().expect("gather output row");
        for j in 0..D {
            row[j] += w[j];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn cb() -> Codebook {
        Codebook::new(4, 2, vec![0., 0., 1., 0., 0., 1., 1., 1.])
    }

    #[test]
    fn decode_roundtrip() {
        let c = cb();
        let codes = [3u32, 0, 1];
        let out = c.decode_vec(&codes);
        assert_eq!(out, vec![1., 1., 0., 0., 1., 0.]);
    }

    /// The fused streaming kernel must equal unpack-then-decode exactly,
    /// on windows that straddle its internal stack-chunk boundary and at
    /// a non-byte width.
    #[test]
    fn decode_packed_into_matches_unpack_then_decode() {
        use crate::vq::pack::pack_codes;

        let mut rng = Rng::new(17);
        let mut words = vec![0.0f32; 16 * 3];
        rng.fill_normal(&mut words);
        let c = Codebook::new(16, 3, words);
        let codes: Vec<u32> = (0..300).map(|_| rng.below(16) as u32).collect();
        let p = pack_codes(&codes, 5);
        for (start, end) in [(0usize, 300usize), (7, 291), (120, 140), (128, 128)] {
            let mut fused = vec![0.0f32; (end - start) * c.d];
            c.decode_packed_into(&p, start, end, &mut fused);
            let direct = c.decode_vec(&codes[start..end]);
            let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
            assert_eq!(bits(&fused), bits(&direct), "[{start}, {end})");
        }
    }

    #[test]
    #[should_panic(expected = "output size")]
    fn decode_packed_into_checks_output_size() {
        use crate::vq::pack::pack_codes;
        let c = cb();
        let p = pack_codes(&[0u32, 1], 2);
        let mut out = vec![0.0f32; 3]; // needs 2 * d = 4
        c.decode_packed_into(&p, 0, 2, &mut out);
    }

    #[test]
    fn encode_nearest_exact_on_codewords() {
        let c = cb();
        let flat = [1.0f32, 1.0, 0.0, 1.0];
        let (mse, codes) = c.encode_nearest(&flat);
        assert_eq!(codes, vec![3, 2]);
        assert_eq!(mse, 0.0);
    }

    #[test]
    fn encode_nearest_error_value() {
        let c = cb();
        // (0.5, 0.0) is 0.25 away (sq) from both (0,0) and (1,0).
        let (mse, _) = c.encode_nearest(&[0.5, 0.0]);
        assert!((mse - 0.125).abs() < 1e-7, "0.25 sq err over 2 weights");
    }

    #[test]
    fn norms_cached_at_construction() {
        let c = cb();
        assert_eq!(c.norms(), &[0.0, 1.0, 1.0, 2.0]);
    }

    /// The pruned encode path (d >= PRUNE_MIN_D) must match the retained
    /// brute-force reference bit for bit — including the f64 MSE, whose
    /// partials it sums over the same chunk schedule.
    #[test]
    fn pruned_encode_matches_reference_at_large_d() {
        let mut rng = Rng::new(37);
        let d = 12; // >= ops::PRUNE_MIN_D: the pruned scan really runs
        let mut words = vec![0.0f32; 32 * d];
        rng.fill_normal(&mut words);
        // Exact duplicate codeword -> argmin ties must break first-index.
        let dup: Vec<f32> = words[3 * d..4 * d].to_vec();
        words[19 * d..20 * d].copy_from_slice(&dup);
        let c = Codebook::new(32, d, words);
        let mut flat = vec![0.0f32; 300 * d];
        rng.fill_normal(&mut flat);
        // Plant exact codewords so zero-distance ties occur.
        let w3: Vec<f32> = c.word(3).to_vec();
        flat[5 * d..6 * d].copy_from_slice(&w3);
        flat[250 * d..251 * d].copy_from_slice(&w3);
        let (m_ref, c_ref) = c.encode_nearest_reference(&flat);
        let (m_new, c_new) = c.encode_nearest_with(&flat, None);
        assert_eq!(m_ref.to_bits(), m_new.to_bits(), "MSE diverged");
        assert_eq!(c_ref, c_new, "codes diverged");
        assert_eq!(c_new[5], 3, "duplicate-codeword tie must keep the first index");
    }

    /// The fused word-level + gathered decode must equal the retained
    /// bit-loop reference across small-d specializations and widths.
    #[test]
    fn fused_decode_matches_reference_kernel() {
        use crate::vq::pack::pack_codes;
        let mut rng = Rng::new(41);
        for d in [1usize, 2, 3, 4, 7] {
            let mut words = vec![0.0f32; 16 * d];
            rng.fill_normal(&mut words);
            let c = Codebook::new(16, d, words);
            let codes: Vec<u32> = (0..300).map(|_| rng.below(16) as u32).collect();
            for bits in [4u32, 5, 13] {
                let p = pack_codes(&codes, bits);
                for (start, end) in [(0usize, 300usize), (17, 291), (297, 300)] {
                    let mut fast = vec![0.0f32; (end - start) * d];
                    let mut slow = vec![0.0f32; (end - start) * d];
                    c.decode_packed_into(&p, start, end, &mut fast);
                    c.decode_packed_into_reference(&p, start, end, &mut slow);
                    let b = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
                    assert_eq!(b(&fast), b(&slow), "d={d} bits={bits} [{start}, {end})");
                }
            }
        }
    }

    /// At stages == 1 the staged decode IS the legacy fused decode:
    /// same bytes in (StagedCodes::single wraps without repacking),
    /// same float bits out.
    #[test]
    fn single_stage_staged_decode_equals_legacy_fused() {
        use crate::vq::pack::{pack_codes, StagedCodes};
        let mut rng = Rng::new(43);
        let mut words = vec![0.0f32; 16 * 3];
        rng.fill_normal(&mut words);
        let c = Codebook::new(16, 3, words);
        let codes: Vec<u32> = (0..300).map(|_| rng.below(16) as u32).collect();
        let p = pack_codes(&codes, 5);
        let staged = StagedCodes::single(p.clone());
        for (start, end) in [(0usize, 300usize), (7, 291), (120, 140)] {
            let mut legacy = vec![0.0f32; (end - start) * c.d];
            let mut staged_out = vec![0.0f32; (end - start) * c.d];
            c.decode_packed_into(&p, start, end, &mut legacy);
            c.decode_staged_packed_into(&staged, start, end, &mut staged_out);
            let b = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
            assert_eq!(b(&legacy), b(&staged_out), "[{start}, {end})");
        }
    }

    /// The fused staged decode (word-level unpack + gather/gather_add)
    /// must equal the retained scalar reference across small-d
    /// specializations, stage counts, and mixed stage widths.
    #[test]
    fn staged_decode_matches_reference_kernel() {
        use crate::vq::pack::{pack_codes, StagedCodes};
        let mut rng = Rng::new(47);
        for d in [1usize, 2, 3, 4, 7] {
            let mut words = vec![0.0f32; 32 * d];
            rng.fill_normal(&mut words);
            let c = Codebook::new(32, d, words);
            for stages in 1..=3usize {
                let streams: Vec<_> = (0..stages)
                    .map(|s| {
                        let bits = [5u32, 3, 13][s];
                        let k = 1usize << bits.min(5);
                        let codes: Vec<u32> =
                            (0..300).map(|_| rng.below(k) as u32).collect();
                        pack_codes(&codes, bits)
                    })
                    .collect();
                let staged = StagedCodes::new(streams);
                for (start, end) in [(0usize, 300usize), (17, 291), (297, 300)] {
                    let mut fast = vec![0.0f32; (end - start) * d];
                    let mut slow = vec![0.0f32; (end - start) * d];
                    c.decode_staged_packed_into(&staged, start, end, &mut fast);
                    c.decode_staged_packed_into_reference(&staged, start, end, &mut slow);
                    let b = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
                    assert_eq!(b(&fast), b(&slow), "d={d} stages={stages} [{start}, {end})");
                }
            }
        }
    }

    /// The specialized staged encode (pruned scan + word-level pack)
    /// must match the brute-force reference exactly — packed bytes, MSE
    /// bits, utilization — and the pooled path must match serial, at a
    /// d where the pruned scan really runs.
    #[test]
    fn staged_encode_matches_reference_and_pooled() {
        let mut rng = Rng::new(53);
        for d in [4usize, 12] {
            let mut words = vec![0.0f32; 64 * d];
            rng.fill_normal(&mut words);
            let c = Codebook::new(64, d, words);
            let mut flat = vec![0.0f32; 300 * d];
            rng.fill_normal(&mut flat);
            let pool = ThreadPool::new(4);
            for stage_bits in [&[6u32][..], &[5u32, 5][..], &[4u32, 3, 5][..]] {
                let reference = c.encode_staged_reference(&flat, stage_bits);
                let serial = c.encode_staged(&flat, stage_bits, None);
                let pooled = c.encode_staged(&flat, stage_bits, Some(&pool));
                for got in [&serial, &pooled] {
                    assert_eq!(reference.codes, got.codes, "d={d} {stage_bits:?}");
                    assert_eq!(
                        reference.mse.to_bits(),
                        got.mse.to_bits(),
                        "d={d} {stage_bits:?} MSE diverged"
                    );
                    let sb = |v: &[f64]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
                    assert_eq!(sb(&reference.stage_mse), sb(&got.stage_mse));
                    assert_eq!(reference.utilization, got.utilization);
                }
            }
        }
    }

    /// Residual round-trip: staged decode of the staged encode must
    /// reconstruct better with more stages on a codebook whose first
    /// word is exactly zero (so a stage can never grow the residual —
    /// the zero word reproduces the incoming error bit for bit) and
    /// whose next words sit at residual scale (so the second stage has
    /// something to say).  The decoded reconstruction error must agree
    /// with the encoder's reported MSE up to f32 re-association.
    #[test]
    fn staged_roundtrip_reduces_error_with_stages() {
        let mut rng = Rng::new(59);
        let d = 4;
        let mut words = vec![0.0f32; 64 * d];
        rng.fill_normal(&mut words);
        words[..d].fill(0.0); // exact zero word: stages are monotone
        for w in words[d..8 * d].iter_mut() {
            *w *= 0.2; // residual-scale words for stage 2 to use
        }
        let c = Codebook::new(64, d, words);
        let mut flat = vec![0.0f32; 200 * d];
        rng.fill_normal(&mut flat);

        let one = c.encode_staged(&flat, &[6], None);
        let two = c.encode_staged(&flat, &[6, 6], None);
        assert!(two.mse < one.mse, "2-stage {} !< 1-stage {}", two.mse, one.mse);
        assert!(two.stage_mse[1] <= two.stage_mse[0]);

        let mut recon = vec![0.0f32; flat.len()];
        c.decode_staged_packed_into(&two.codes, 0, 200, &mut recon);
        let mse: f64 = flat
            .iter()
            .zip(&recon)
            .map(|(a, b)| ((a - b) as f64).powi(2))
            .sum::<f64>()
            / flat.len() as f64;
        assert!(
            (mse - two.mse).abs() <= 1e-4 * (1.0 + two.mse.abs()),
            "decode MSE {mse} vs encoder-reported {}",
            two.mse
        );
    }

    /// stage_k is a pure prefix restriction of the one codebook.
    #[test]
    fn stage_k_is_a_prefix_of_the_codebook() {
        let c = Codebook::new(64, 2, vec![0.0; 128]);
        assert_eq!(c.stage_k(3), 8);
        assert_eq!(c.stage_k(6), 64);
        assert_eq!(c.stage_k(10), 64);
        assert_eq!(c.stage_k(32), 64);
    }

    #[test]
    fn weighted_decode_matches_hard_at_onehot() {
        let c = cb();
        let assign = [0u32, 3, 1, 2]; // 2 groups, n=2
        let ratios = [1.0f32, 0.0, 0.0, 1.0];
        let mut out = vec![0.0; 4];
        c.decode_weighted(&assign, &ratios, 2, &mut out);
        assert_eq!(out, vec![0., 0., 0., 1.]);
    }

    #[test]
    fn weighted_decode_mixes() {
        let c = cb();
        let assign = [1u32, 2]; // one group, n=2: (1,0) and (0,1)
        let ratios = [0.5f32, 0.5];
        let mut out = vec![0.0; 2];
        c.decode_weighted(&assign, &ratios, 2, &mut out);
        assert_eq!(out, vec![0.5, 0.5]);
    }

    #[test]
    fn storage_and_bits() {
        let c = Codebook::new(256, 4, vec![0.0; 1024]);
        assert_eq!(c.storage_bytes(), 4096);
        assert_eq!(c.bits_per_weight(), 2.0);
        assert_eq!(c.index_bits(), 8);
        let c2 = Codebook::new(65536, 8, vec![0.0; 65536 * 8]);
        assert_eq!(c2.bits_per_weight(), 2.0);
        assert_eq!(c2.index_bits(), 16);
    }

    /// The PRUNE_MIN_D boundary, pinned: d = 7 must take the naive scan
    /// and d = 8 the pruned one, in both the single-stage and the staged
    /// encode — and on both sides of the line the output must match the
    /// brute-force references bit for bit (the boundary is a perf knob,
    /// never a semantics knob).
    #[test]
    fn prune_boundary_d7_naive_d8_pruned_single_stage() {
        assert!(!ops::prunes_at(7), "d = 7 must stay on the naive scan");
        assert!(ops::prunes_at(8), "d = 8 must take the pruned scan");
        let mut rng = Rng::new(61);
        for d in [7usize, 8] {
            let mut words = vec![0.0f32; 32 * d];
            rng.fill_normal(&mut words);
            let c = Codebook::new(32, d, words);
            let mut flat = vec![0.0f32; 300 * d];
            rng.fill_normal(&mut flat);
            // Plant an exact codeword so a zero-distance tie occurs.
            let w5: Vec<f32> = c.word(5).to_vec();
            flat[40 * d..41 * d].copy_from_slice(&w5);
            let (m_ref, c_ref) = c.encode_nearest_reference(&flat);
            let (m_new, c_new) = c.encode_nearest_with(&flat, None);
            assert_eq!(m_ref.to_bits(), m_new.to_bits(), "d={d} MSE diverged");
            assert_eq!(c_ref, c_new, "d={d} codes diverged");
        }
    }

    #[test]
    fn prune_boundary_d7_naive_d8_pruned_staged() {
        let mut rng = Rng::new(67);
        for d in [7usize, 8] {
            let mut words = vec![0.0f32; 64 * d];
            rng.fill_normal(&mut words);
            let c = Codebook::new(64, d, words);
            let mut flat = vec![0.0f32; 260 * d];
            rng.fill_normal(&mut flat);
            let reference = c.encode_staged_reference(&flat, &[5, 4]);
            let got = c.encode_staged(&flat, &[5, 4], None);
            assert_eq!(reference.codes, got.codes, "d={d} staged codes diverged");
            assert_eq!(reference.mse.to_bits(), got.mse.to_bits(), "d={d} staged MSE");
            assert_eq!(reference.utilization, got.utilization, "d={d}");
        }
    }

    /// Wide-d decode rides the runtime-dispatched SIMD gather (and the
    /// staged decode its accumulate twin): both must stay bit-identical
    /// to the scalar references across the 7/8 dispatch boundary and at
    /// ragged widths (d % 8 != 0 exercises the tail lanes).
    #[test]
    fn wide_d_fused_and_staged_decode_match_references() {
        use crate::vq::pack::{pack_codes, StagedCodes};
        let mut rng = Rng::new(71);
        for d in [8usize, 9, 12, 16, 19] {
            let mut words = vec![0.0f32; 32 * d];
            rng.fill_normal(&mut words);
            let c = Codebook::new(32, d, words);
            let codes: Vec<u32> = (0..300).map(|_| rng.below(32) as u32).collect();
            let p = pack_codes(&codes, 5);
            for (start, end) in [(0usize, 300usize), (17, 291), (297, 300)] {
                let mut fast = vec![0.0f32; (end - start) * d];
                let mut slow = vec![0.0f32; (end - start) * d];
                c.decode_packed_into(&p, start, end, &mut fast);
                c.decode_packed_into_reference(&p, start, end, &mut slow);
                let b = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
                assert_eq!(b(&fast), b(&slow), "d={d} [{start}, {end})");
            }
            let streams: Vec<_> = (0..2)
                .map(|_| {
                    let codes: Vec<u32> = (0..300).map(|_| rng.below(32) as u32).collect();
                    pack_codes(&codes, 5)
                })
                .collect();
            let staged = StagedCodes::new(streams);
            let mut fast = vec![0.0f32; 300 * d];
            let mut slow = vec![0.0f32; 300 * d];
            c.decode_staged_packed_into(&staged, 0, 300, &mut fast);
            c.decode_staged_packed_into_reference(&staged, 0, 300, &mut slow);
            let b = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
            assert_eq!(b(&fast), b(&slow), "staged d={d}");
        }
    }

    /// The decode-side determinism contract: pooled encode/decode paths
    /// are bit-identical to serial on workloads that really split
    /// (s > CHUNK), including the f64 MSE reduction.
    #[test]
    fn parallel_encode_decode_bit_identical_to_serial() {
        let mut rng = Rng::new(31);
        let d = 4;
        let s = 1000; // > CHUNK so the pooled path really splits
        let mut words = vec![0.0f32; 16 * d];
        rng.fill_normal(&mut words);
        let c = Codebook::new(16, d, words);
        let mut flat = vec![0.0f32; s * d];
        rng.fill_normal(&mut flat);
        let pool = ThreadPool::new(4);

        let (m1, codes1) = c.encode_nearest_with(&flat, None);
        let (m2, codes2) = c.encode_nearest_with(&flat, Some(&pool));
        assert_eq!(m1.to_bits(), m2.to_bits(), "MSE reduction diverged");
        assert_eq!(codes1, codes2);

        let mut o1 = vec![0.0f32; s * d];
        let mut o2 = vec![0.0f32; s * d];
        c.decode_with(&codes1, &mut o1, None);
        c.decode_with(&codes1, &mut o2, Some(&pool));
        assert_eq!(o1, o2);

        let n = 3;
        let mut ratios = vec![0.0f32; s * n];
        rng.fill_normal(&mut ratios);
        let assign: Vec<u32> = (0..s * n).map(|_| rng.below(16) as u32).collect();
        let mut w1 = vec![0.0f32; s * d];
        let mut w2 = vec![0.0f32; s * d];
        c.decode_weighted_with(&assign, &ratios, n, &mut w1, None);
        c.decode_weighted_with(&assign, &ratios, n, &mut w2, Some(&pool));
        let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&w1), bits(&w2));
    }
}
