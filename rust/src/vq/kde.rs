//! Kernel-density-estimation codebook sampler (§4.1, Eq. 3–4).
//!
//! The universal codebook is drawn from the Gaussian KDE of an
//! equal-count sub-vector pool across all zoo networks.  For a Gaussian
//! kernel, sampling the KDE is exact: pick a pool vector uniformly, add
//! `N(0, h^2 I)` noise — no density grid required.  Density *evaluation*
//! (for the Table-6 analyses and cross-checking the python artifact) is
//! also provided.

use crate::tensor::ops;
use crate::util::rng::Rng;

use super::codebook::Codebook;

/// KDE over a `(n, d)` sample pool with bandwidth `h`.
#[derive(Clone, Debug)]
pub struct KdeSampler {
    pub d: usize,
    pub bandwidth: f32,
    pool: Vec<f32>, // (n, d) row-major
}

impl KdeSampler {
    pub fn new(pool: Vec<f32>, d: usize, bandwidth: f32) -> Self {
        assert!(d > 0 && bandwidth > 0.0);
        assert!(!pool.is_empty() && pool.len() % d == 0, "pool must be (n, d)");
        KdeSampler { d, bandwidth, pool }
    }

    /// Equal-count pool construction (§4.1: "randomly sample an equal
    /// number of weight sub-vectors from each network ... ensuring that
    /// the codebook remains unbiased").
    pub fn pool_from_networks(flats: &[&[f32]], d: usize, per_net: usize, rng: &mut Rng) -> Vec<f32> {
        let mut pool = Vec::with_capacity(flats.len() * per_net * d);
        for flat in flats {
            assert_eq!(flat.len() % d, 0);
            let s = flat.len() / d;
            if s >= per_net {
                for idx in rng.sample_without_replacement(s, per_net) {
                    pool.extend_from_slice(&flat[idx * d..(idx + 1) * d]);
                }
            } else {
                for _ in 0..per_net {
                    let idx = rng.below(s);
                    pool.extend_from_slice(&flat[idx * d..(idx + 1) * d]);
                }
            }
        }
        pool
    }

    pub fn n(&self) -> usize {
        self.pool.len() / self.d
    }

    /// Draw one sample from the KDE.
    pub fn sample(&self, rng: &mut Rng) -> Vec<f32> {
        let i = rng.below(self.n());
        let base = &self.pool[i * self.d..(i + 1) * self.d];
        base.iter()
            .map(|&x| x + rng.normal_f32(0.0, self.bandwidth))
            .collect()
    }

    /// Draw a `(k, d)` frozen universal codebook (Eq. 4).
    pub fn sample_codebook(&self, k: usize, rng: &mut Rng) -> Codebook {
        let mut words = Vec::with_capacity(k * self.d);
        for _ in 0..k {
            words.extend(self.sample(rng));
        }
        Codebook::new(k, self.d, words)
    }

    /// Evaluate the KDE density at `q` (Eq. 3, product Gaussian kernel).
    pub fn density(&self, q: &[f32]) -> f64 {
        assert_eq!(q.len(), self.d);
        let h2 = (self.bandwidth as f64) * (self.bandwidth as f64);
        let log_norm = -0.5 * self.d as f64 * (2.0 * std::f64::consts::PI * h2).ln();
        let mut acc = 0.0f64;
        for i in 0..self.n() {
            let s = &self.pool[i * self.d..(i + 1) * self.d];
            let sq = ops::sq_dist(q, s) as f64;
            acc += (-0.5 * sq / h2 + log_norm).exp();
        }
        acc / self.n() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn samples_stay_near_pool() {
        // Pool concentrated at (5, 5); bandwidth small -> samples near it.
        let pool = vec![5.0f32; 2 * 100];
        let kde = KdeSampler::new(pool, 2, 0.01);
        let mut rng = Rng::new(1);
        for _ in 0..100 {
            let s = kde.sample(&mut rng);
            assert!((s[0] - 5.0).abs() < 0.1 && (s[1] - 5.0).abs() < 0.1);
        }
    }

    #[test]
    fn codebook_moments_match_pool() {
        // Pool ~ N(0, 1): sampled codebook mean ~ 0, var ~ 1 + h^2.
        let mut rng = Rng::new(2);
        let mut pool = vec![0.0f32; 4 * 5000];
        rng.fill_normal(&mut pool);
        let kde = KdeSampler::new(pool, 4, 0.1);
        let cb = kde.sample_codebook(2000, &mut rng);
        let mean: f32 = cb.words.iter().sum::<f32>() / cb.words.len() as f32;
        let var: f32 =
            cb.words.iter().map(|&x| (x - mean) * (x - mean)).sum::<f32>() / cb.words.len() as f32;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.01).abs() < 0.1, "var {var}");
    }

    #[test]
    fn density_peaks_on_data() {
        let pool = vec![0.0f32; 2 * 50];
        let kde = KdeSampler::new(pool, 2, 0.5);
        assert!(kde.density(&[0.0, 0.0]) > kde.density(&[3.0, 3.0]) * 10.0);
    }

    #[test]
    fn density_integrates_1d() {
        // 1-d KDE over {0}: integral over fine grid ~ 1.
        let kde = KdeSampler::new(vec![0.0f32], 1, 0.3);
        let mut acc = 0.0;
        let step = 0.01;
        let mut x = -3.0f32;
        while x < 3.0 {
            acc += kde.density(&[x]) * step as f64;
            x += step;
        }
        assert!((acc - 1.0).abs() < 0.01, "integral {acc}");
    }

    #[test]
    fn equal_count_pool() {
        let mut rng = Rng::new(3);
        let a = vec![1.0f32; 10 * 2]; // 10 subvectors of d=2, all ones
        let b = vec![2.0f32; 50 * 2];
        let pool = KdeSampler::pool_from_networks(&[&a, &b], 2, 8, &mut rng);
        assert_eq!(pool.len(), 2 * 8 * 2);
        let ones = pool.iter().filter(|&&x| x == 1.0).count();
        let twos = pool.iter().filter(|&&x| x == 2.0).count();
        assert_eq!(ones, 16, "equal count from each network");
        assert_eq!(twos, 16);
    }

    #[test]
    fn small_net_sampled_with_replacement() {
        let mut rng = Rng::new(4);
        let tiny = vec![3.0f32; 2 * 2]; // only 2 sub-vectors
        let pool = KdeSampler::pool_from_networks(&[&tiny], 2, 10, &mut rng);
        assert_eq!(pool.len(), 10 * 2);
    }
}
