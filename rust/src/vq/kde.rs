//! Kernel-density-estimation codebook sampler (§4.1, Eq. 3–4).
//!
//! The universal codebook is drawn from the Gaussian KDE of an
//! equal-count sub-vector pool across all zoo networks.  For a Gaussian
//! kernel, sampling the KDE is exact: pick a pool vector uniformly, add
//! `N(0, h^2 I)` noise — no density grid required.  Density *evaluation*
//! (for the Table-6 analyses and cross-checking the python artifact) is
//! also provided.

use crate::tensor::ops;
use crate::util::rng::Rng;
use crate::util::threadpool::{SyncPtr, ThreadPool};

use super::codebook::Codebook;

/// Codewords per scheduling chunk when sampling a codebook; fixed so
/// per-chunk RNG streams are thread-count independent.
const SAMPLE_CHUNK: usize = 64;

/// Pool points per chunk for density evaluation; partial sums reduce in
/// chunk order so the f64 total is scheduling-independent.
const DENSITY_CHUNK: usize = 256;

/// KDE over a `(n, d)` sample pool with bandwidth `h`.
#[derive(Clone, Debug)]
pub struct KdeSampler {
    pub d: usize,
    pub bandwidth: f32,
    pool: Vec<f32>, // (n, d) row-major
}

impl KdeSampler {
    pub fn new(pool: Vec<f32>, d: usize, bandwidth: f32) -> Self {
        assert!(d > 0 && bandwidth > 0.0);
        assert!(!pool.is_empty() && pool.len() % d == 0, "pool must be (n, d)");
        KdeSampler { d, bandwidth, pool }
    }

    /// Equal-count pool construction (§4.1: "randomly sample an equal
    /// number of weight sub-vectors from each network ... ensuring that
    /// the codebook remains unbiased").  Serial entry point — identical
    /// output to [`KdeSampler::pool_from_networks_with`] at any thread
    /// count.
    pub fn pool_from_networks(flats: &[&[f32]], d: usize, per_net: usize, rng: &mut Rng) -> Vec<f32> {
        Self::pool_from_networks_with(flats, d, per_net, rng, None)
    }

    /// Equal-count pool construction, one pool job per network.  Every
    /// network's sub-vector picks come from a stream seeded by its index,
    /// so the pool is a pure function of `(flats, d, per_net, rng seed)`
    /// regardless of worker interleaving.
    pub fn pool_from_networks_with(
        flats: &[&[f32]],
        d: usize,
        per_net: usize,
        rng: &mut Rng,
        pool: Option<&ThreadPool>,
    ) -> Vec<f32> {
        for flat in flats {
            assert_eq!(flat.len() % d, 0);
            assert!(!flat.is_empty(), "network with no sub-vectors");
        }
        let base = rng.next_u64();
        let mut out = vec![0.0f32; flats.len() * per_net * d];

        let kernel = |i: usize, dst: &mut [f32]| {
            let mut nrng = Rng::chunk_stream(base, i);
            let flat = flats[i];
            let s = flat.len() / d;
            if s >= per_net {
                for (slot, idx) in nrng.sample_without_replacement(s, per_net).into_iter().enumerate() {
                    dst[slot * d..(slot + 1) * d].copy_from_slice(&flat[idx * d..(idx + 1) * d]);
                }
            } else {
                for slot in 0..per_net {
                    let idx = nrng.below(s);
                    dst[slot * d..(slot + 1) * d].copy_from_slice(&flat[idx * d..(idx + 1) * d]);
                }
            }
        };

        match pool {
            Some(tp) if tp.threads() > 1 && flats.len() > 1 => {
                let out_ptr = SyncPtr::new(&mut out);
                tp.parallel_for(flats.len(), 1, |start, end| {
                    for i in start..end {
                        // SAFETY: each network owns a disjoint window.
                        let dst = unsafe { out_ptr.slice(i * per_net * d, per_net * d) };
                        kernel(i, dst);
                    }
                })
                .expect("KDE pool construction worker panicked");
            }
            _ => {
                for i in 0..flats.len() {
                    kernel(i, &mut out[i * per_net * d..(i + 1) * per_net * d]);
                }
            }
        }
        out
    }

    pub fn n(&self) -> usize {
        self.pool.len() / self.d
    }

    /// Draw one sample from the KDE.
    pub fn sample(&self, rng: &mut Rng) -> Vec<f32> {
        let i = rng.below(self.n());
        let base = &self.pool[i * self.d..(i + 1) * self.d];
        base.iter()
            .map(|&x| x + rng.normal_f32(0.0, self.bandwidth))
            .collect()
    }

    /// Draw a `(k, d)` frozen universal codebook (Eq. 4).  Serial entry
    /// point — identical output to [`KdeSampler::sample_codebook_with`]
    /// at any thread count.
    pub fn sample_codebook(&self, k: usize, rng: &mut Rng) -> Codebook {
        self.sample_codebook_with(k, rng, None)
    }

    /// Draw a `(k, d)` codebook with the draws spread over fixed
    /// codeword chunks, each chunk on its own index-derived RNG stream.
    pub fn sample_codebook_with(&self, k: usize, rng: &mut Rng, pool: Option<&ThreadPool>) -> Codebook {
        let base = rng.next_u64();
        let mut words = vec![0.0f32; k * self.d];

        let kernel = |start: usize, end: usize, dst: &mut [f32]| {
            let mut crng = Rng::chunk_stream(base, start / SAMPLE_CHUNK);
            for w in 0..(end - start) {
                let i = crng.below(self.n());
                let src = &self.pool[i * self.d..(i + 1) * self.d];
                let out = &mut dst[w * self.d..(w + 1) * self.d];
                for (o, &x) in out.iter_mut().zip(src) {
                    *o = x + crng.normal_f32(0.0, self.bandwidth);
                }
            }
        };

        match pool {
            Some(tp) if tp.threads() > 1 && k > SAMPLE_CHUNK => {
                let words_ptr = SyncPtr::new(&mut words);
                tp.parallel_for(k, SAMPLE_CHUNK, |start, end| {
                    // SAFETY: disjoint codeword windows per chunk.
                    let dst = unsafe { words_ptr.slice(start * self.d, (end - start) * self.d) };
                    kernel(start, end, dst);
                })
                .expect("KDE codebook sampling worker panicked");
            }
            _ => {
                let mut start = 0;
                while start < k {
                    let end = (start + SAMPLE_CHUNK).min(k);
                    kernel(start, end, &mut words[start * self.d..end * self.d]);
                    start = end;
                }
            }
        }
        Codebook::new(k, self.d, words)
    }

    /// Evaluate the KDE density at `q` (Eq. 3, product Gaussian kernel).
    /// Serial entry point — identical to [`KdeSampler::density_with`].
    pub fn density(&self, q: &[f32]) -> f64 {
        self.density_with(q, None)
    }

    /// Density evaluation with the kernel sum spread over fixed pool
    /// chunks; per-chunk partials reduce in chunk order so the f64 total
    /// is bit-identical at every thread count.
    pub fn density_with(&self, q: &[f32], pool: Option<&ThreadPool>) -> f64 {
        assert_eq!(q.len(), self.d);
        let h2 = (self.bandwidth as f64) * (self.bandwidth as f64);
        let log_norm = -0.5 * self.d as f64 * (2.0 * std::f64::consts::PI * h2).ln();
        let n = self.n();
        let nchunks = n.div_ceil(DENSITY_CHUNK);
        let mut partials = vec![0.0f64; nchunks];

        let kernel = |start: usize, end: usize| -> f64 {
            let mut acc = 0.0f64;
            for i in start..end {
                let s = &self.pool[i * self.d..(i + 1) * self.d];
                let sq = ops::sq_dist(q, s) as f64;
                acc += (-0.5 * sq / h2 + log_norm).exp();
            }
            acc
        };

        match pool {
            Some(tp) if tp.threads() > 1 && n > DENSITY_CHUNK => {
                let part_ptr = SyncPtr::new(&mut partials);
                tp.note_read(&self.pool);
                tp.note_read(q);
                tp.parallel_for(n, DENSITY_CHUNK, |start, end| {
                    let p = kernel(start, end);
                    // SAFETY: one slot per chunk index.
                    unsafe { part_ptr.slice(start / DENSITY_CHUNK, 1)[0] = p };
                })
                .expect("KDE density worker panicked");
            }
            _ => {
                let mut start = 0;
                while start < n {
                    let end = (start + DENSITY_CHUNK).min(n);
                    partials[start / DENSITY_CHUNK] = kernel(start, end);
                    start = end;
                }
            }
        }
        partials.iter().sum::<f64>() / n as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn samples_stay_near_pool() {
        // Pool concentrated at (5, 5); bandwidth small -> samples near it.
        let pool = vec![5.0f32; 2 * 100];
        let kde = KdeSampler::new(pool, 2, 0.01);
        let mut rng = Rng::new(1);
        for _ in 0..100 {
            let s = kde.sample(&mut rng);
            assert!((s[0] - 5.0).abs() < 0.1 && (s[1] - 5.0).abs() < 0.1);
        }
    }

    #[test]
    fn codebook_moments_match_pool() {
        // Pool ~ N(0, 1): sampled codebook mean ~ 0, var ~ 1 + h^2.
        let mut rng = Rng::new(2);
        let mut pool = vec![0.0f32; 4 * 5000];
        rng.fill_normal(&mut pool);
        let kde = KdeSampler::new(pool, 4, 0.1);
        let cb = kde.sample_codebook(2000, &mut rng);
        let mean: f32 = cb.words.iter().sum::<f32>() / cb.words.len() as f32;
        let var: f32 =
            cb.words.iter().map(|&x| (x - mean) * (x - mean)).sum::<f32>() / cb.words.len() as f32;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.01).abs() < 0.1, "var {var}");
    }

    #[test]
    fn density_peaks_on_data() {
        let pool = vec![0.0f32; 2 * 50];
        let kde = KdeSampler::new(pool, 2, 0.5);
        assert!(kde.density(&[0.0, 0.0]) > kde.density(&[3.0, 3.0]) * 10.0);
    }

    #[test]
    fn density_integrates_1d() {
        // 1-d KDE over {0}: integral over fine grid ~ 1.
        let kde = KdeSampler::new(vec![0.0f32], 1, 0.3);
        let mut acc = 0.0;
        let step = 0.01;
        let mut x = -3.0f32;
        while x < 3.0 {
            acc += kde.density(&[x]) * step as f64;
            x += step;
        }
        assert!((acc - 1.0).abs() < 0.01, "integral {acc}");
    }

    #[test]
    fn equal_count_pool() {
        let mut rng = Rng::new(3);
        let a = vec![1.0f32; 10 * 2]; // 10 subvectors of d=2, all ones
        let b = vec![2.0f32; 50 * 2];
        let pool = KdeSampler::pool_from_networks(&[&a, &b], 2, 8, &mut rng);
        assert_eq!(pool.len(), 2 * 8 * 2);
        let ones = pool.iter().filter(|&&x| x == 1.0).count();
        let twos = pool.iter().filter(|&&x| x == 2.0).count();
        assert_eq!(ones, 16, "equal count from each network");
        assert_eq!(twos, 16);
    }

    #[test]
    fn parallel_paths_bit_identical_to_serial() {
        let mut rng = Rng::new(5);
        let mut pool_data = vec![0.0f32; 4 * 3000];
        rng.fill_normal(&mut pool_data);
        let kde = KdeSampler::new(pool_data.clone(), 4, 0.05);
        let tp = ThreadPool::new(4);

        // Codebook sampling: same seed, serial vs pooled.
        let a = kde.sample_codebook(300, &mut Rng::new(41));
        let b = kde.sample_codebook_with(300, &mut Rng::new(41), Some(&tp));
        assert_eq!(a.words, b.words);

        // Density: exact partial-sum grouping on both paths.
        let q = [0.1f32, -0.2, 0.3, 0.0];
        assert_eq!(
            kde.density(&q).to_bits(),
            kde.density_with(&q, Some(&tp)).to_bits()
        );

        // Pool construction: per-network streams.
        let n1 = vec![1.0f32; 40 * 4];
        let n2 = vec![2.0f32; 90 * 4];
        let n3 = vec![3.0f32; 5 * 4];
        let flats: Vec<&[f32]> = vec![&n1, &n2, &n3];
        let p1 = KdeSampler::pool_from_networks(&flats, 4, 20, &mut Rng::new(6));
        let p2 = KdeSampler::pool_from_networks_with(&flats, 4, 20, &mut Rng::new(6), Some(&tp));
        assert_eq!(p1, p2);
    }

    #[test]
    fn small_net_sampled_with_replacement() {
        let mut rng = Rng::new(4);
        let tiny = vec![3.0f32; 2 * 2]; // only 2 sub-vectors
        let pool = KdeSampler::pool_from_networks(&[&tiny], 2, 10, &mut rng);
        assert_eq!(pool.len(), 10 * 2);
    }
}
