//! k-means (k-means++ init, Lloyd iterations) — the per-layer VQ
//! baseline (DeepCompression / BGD / PQF / DKM all start here) and the
//! paper's "special layer" per-layer codebooks (§5).
//!
//! Multi-threaded assignment sweeps via the in-house pool; deterministic
//! given the seed.

use crate::tensor::ops;
use crate::util::rng::Rng;
use crate::util::threadpool::ThreadPool;

use super::codebook::Codebook;

/// Result of a k-means run.
#[derive(Clone, Debug)]
pub struct KmeansResult {
    pub codebook: Codebook,
    pub codes: Vec<u32>,
    /// Mean squared error per weight (not per sub-vector).
    pub mse: f64,
    pub iterations: usize,
}

/// Options for [`kmeans`].
#[derive(Clone, Debug)]
pub struct KmeansOpts {
    pub max_iters: usize,
    /// Stop when relative MSE improvement drops below this.
    pub tol: f64,
    pub seed: u64,
    /// Worker threads for the assignment sweep (0 = all cores).
    pub threads: usize,
}

impl Default for KmeansOpts {
    fn default() -> Self {
        KmeansOpts {
            max_iters: 25,
            tol: 1e-4,
            seed: 0,
            threads: 0,
        }
    }
}

/// Cluster `(s, d)` sub-vectors into `k` codewords.
pub fn kmeans(flat: &[f32], d: usize, k: usize, opts: &KmeansOpts) -> KmeansResult {
    assert!(d > 0 && flat.len() % d == 0, "flat must be (s, d)");
    let s = flat.len() / d;
    assert!(s > 0, "empty input");
    let k = k.min(s); // cannot have more clusters than points
    let mut rng = Rng::new(opts.seed);

    let mut centers = kmeanspp_init(flat, s, d, k, &mut rng);
    let mut codes = vec![0u32; s];
    let pool = ThreadPool::new(opts.threads.min(8));
    #[allow(unused_assignments)]
    let mut prev_mse = f64::INFINITY;
    let mut iters = 0;

    for it in 0..opts.max_iters {
        iters = it + 1;
        // Assignment sweep (parallel over sub-vector ranges).
        let mse = assign_sweep(flat, &centers, d, k, &mut codes, &pool);

        // Update step.
        let mut sums = vec![0.0f64; k * d];
        let mut counts = vec![0usize; k];
        for g in 0..s {
            let c = codes[g] as usize;
            counts[c] += 1;
            for j in 0..d {
                sums[c * d + j] += flat[g * d + j] as f64;
            }
        }
        for c in 0..k {
            if counts[c] == 0 {
                // Re-seed empty cluster at a random point (standard fix).
                let g = rng.below(s);
                centers[c * d..(c + 1) * d].copy_from_slice(&flat[g * d..(g + 1) * d]);
            } else {
                for j in 0..d {
                    centers[c * d + j] = (sums[c * d + j] / counts[c] as f64) as f32;
                }
            }
        }

        if prev_mse.is_finite() && (prev_mse - mse) / prev_mse.max(1e-30) < opts.tol {
            break;
        }
        prev_mse = mse;
    }

    // Final assignment against the final centers.
    let mse = assign_sweep(flat, &centers, d, k, &mut codes, &pool);
    KmeansResult {
        codebook: Codebook::new(k, d, centers),
        codes,
        mse,
        iterations: iters,
    }
}

fn assign_sweep(
    flat: &[f32],
    centers: &[f32],
    d: usize,
    k: usize,
    codes: &mut [u32],
    pool: &ThreadPool,
) -> f64 {
    let s = codes.len();
    // Parallel over chunks; each worker writes a disjoint codes range and
    // returns its partial error via an atomic-free per-chunk buffer.
    let nchunks = pool.threads().max(1);
    let chunk = (s + nchunks - 1) / nchunks;
    let errs = std::sync::Mutex::new(vec![0.0f64; nchunks]);
    std::thread::scope(|scope| {
        for (ci, codes_chunk) in codes.chunks_mut(chunk).enumerate() {
            let start = ci * chunk;
            let errs = &errs;
            scope.spawn(move || {
                let mut local = 0.0f64;
                for (off, code) in codes_chunk.iter_mut().enumerate() {
                    let g = start + off;
                    let sub = &flat[g * d..(g + 1) * d];
                    let mut best = 0usize;
                    let mut best_d = f32::INFINITY;
                    for c in 0..k {
                        let dist = ops::sq_dist(sub, &centers[c * d..(c + 1) * d]);
                        if dist < best_d {
                            best_d = dist;
                            best = c;
                        }
                    }
                    *code = best as u32;
                    local += best_d as f64;
                }
                errs.lock().unwrap()[ci] = local;
            });
        }
    });
    let total: f64 = errs.into_inner().unwrap().iter().sum();
    total / flat.len() as f64
}

/// k-means++ seeding: D^2-weighted center selection.
fn kmeanspp_init(flat: &[f32], s: usize, d: usize, k: usize, rng: &mut Rng) -> Vec<f32> {
    let mut centers = Vec::with_capacity(k * d);
    let first = rng.below(s);
    centers.extend_from_slice(&flat[first * d..(first + 1) * d]);
    let mut dist2 = vec![f32::INFINITY; s];
    for c in 1..k {
        let last = &centers[(c - 1) * d..c * d];
        let mut total = 0.0f64;
        for g in 0..s {
            let dd = ops::sq_dist(&flat[g * d..(g + 1) * d], last);
            if dd < dist2[g] {
                dist2[g] = dd;
            }
            total += dist2[g] as f64;
        }
        let pick = if total <= 0.0 {
            rng.below(s)
        } else {
            let mut target = rng.uniform() * total;
            let mut chosen = s - 1;
            for g in 0..s {
                target -= dist2[g] as f64;
                if target <= 0.0 {
                    chosen = g;
                    break;
                }
            }
            chosen
        };
        centers.extend_from_slice(&flat[pick * d..(pick + 1) * d]);
    }
    centers
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Three tight clusters -> k-means with k=3 must hit ~0 error.
    #[test]
    fn recovers_separated_clusters() {
        let mut rng = Rng::new(5);
        let centers = [[0.0f32, 0.0], [10.0, 0.0], [0.0, 10.0]];
        let mut flat = Vec::new();
        for i in 0..300 {
            let c = centers[i % 3];
            flat.push(c[0] + rng.normal_f32(0.0, 0.05));
            flat.push(c[1] + rng.normal_f32(0.0, 0.05));
        }
        let res = kmeans(&flat, 2, 3, &KmeansOpts::default());
        assert!(res.mse < 0.01, "mse {}", res.mse);
        // All three clusters used.
        let used: std::collections::HashSet<_> = res.codes.iter().collect();
        assert_eq!(used.len(), 3);
    }

    #[test]
    fn mse_decreases_with_k() {
        let mut rng = Rng::new(6);
        let mut flat = vec![0.0f32; 2 * 500];
        rng.fill_normal(&mut flat);
        let m2 = kmeans(&flat, 2, 2, &KmeansOpts::default()).mse;
        let m16 = kmeans(&flat, 2, 16, &KmeansOpts::default()).mse;
        let m64 = kmeans(&flat, 2, 64, &KmeansOpts::default()).mse;
        assert!(m2 > m16 && m16 > m64, "{m2} > {m16} > {m64}");
    }

    #[test]
    fn k_clamped_to_points() {
        let flat = [1.0f32, 2.0, 3.0, 4.0]; // 2 points, d=2
        let res = kmeans(&flat, 2, 100, &KmeansOpts::default());
        assert_eq!(res.codebook.k, 2);
        assert!(res.mse < 1e-12);
    }

    #[test]
    fn deterministic_given_seed() {
        let mut rng = Rng::new(7);
        let mut flat = vec![0.0f32; 4 * 200];
        rng.fill_normal(&mut flat);
        let a = kmeans(&flat, 4, 8, &KmeansOpts::default());
        let b = kmeans(&flat, 4, 8, &KmeansOpts::default());
        assert_eq!(a.codes, b.codes);
        assert_eq!(a.codebook.words, b.codebook.words);
    }
}
