//! k-means (k-means++ init, Lloyd iterations) — the per-layer VQ
//! baseline (DeepCompression / BGD / PQF / DKM all start here) and the
//! paper's "special layer" per-layer codebooks (§5).
//!
//! Multi-threaded assignment sweeps via the in-house pool; deterministic
//! given the seed *and independent of the thread count*: the sweeps are
//! chunked on a fixed granularity and every float reduction sums
//! per-chunk partials in chunk order, so `threads = 1` and `threads = N`
//! produce bit-identical codebooks, codes, and MSE (property-tested in
//! `rust/tests/prop_substrate.rs`).
//!
//! This baseline fits a *fresh* codebook per layer; the universal-
//! codebook counterpart for closing the same accuracy gap without new
//! codebook storage is residual staging — `Codebook::encode_staged` /
//! [`super::pack::StagedCodes`] — which re-scans prefixes of the one
//! frozen codebook instead of training new centroids (see
//! `exp/stages.rs` for the matched-total-bits comparison).

use crate::tensor::ops;
use crate::util::rng::Rng;
use crate::util::threadpool::{SyncPtr, ThreadPool};

use super::codebook::Codebook;

/// Sub-vectors per scheduling chunk for the assignment / distance sweeps.
/// Fixed — never derived from the worker count — so the reduction
/// grouping is identical at every parallelism setting.
const CHUNK: usize = 128;

/// Result of a k-means run.
#[derive(Clone, Debug)]
pub struct KmeansResult {
    pub codebook: Codebook,
    pub codes: Vec<u32>,
    /// Mean squared error per weight (not per sub-vector).
    pub mse: f64,
    pub iterations: usize,
}

/// Options for [`kmeans`].
#[derive(Clone, Debug)]
pub struct KmeansOpts {
    pub max_iters: usize,
    /// Stop when relative MSE improvement drops below this.
    pub tol: f64,
    pub seed: u64,
    /// Worker threads for the sweeps (0 = all cores, 1 = serial).
    pub threads: usize,
}

impl Default for KmeansOpts {
    fn default() -> Self {
        KmeansOpts {
            max_iters: 25,
            tol: 1e-4,
            seed: 0,
            threads: 0,
        }
    }
}

/// Cluster `(s, d)` sub-vectors into `k` codewords.  Spawns its own
/// worker pool per `opts.threads` — but only when the input is large
/// enough for a sweep to actually use it, so small inputs (special-layer
/// heads, unit tests) never pay spawn/teardown.  Callers that already
/// hold a pool should use [`kmeans_with`].
pub fn kmeans(flat: &[f32], d: usize, k: usize, opts: &KmeansOpts) -> KmeansResult {
    assert!(d > 0 && flat.len() % d == 0, "flat must be (s, d)");
    let s = flat.len() / d;
    let own = if opts.threads != 1 && s > CHUNK {
        Some(ThreadPool::new(opts.threads))
    } else {
        None
    };
    kmeans_with(flat, d, k, opts, own.as_ref())
}

/// [`kmeans`] on a caller-provided pool (`None` = serial).  Output is
/// bit-identical at every parallelism setting, so passing a shared pool
/// never changes results — only wall-clock.
pub fn kmeans_with(
    flat: &[f32],
    d: usize,
    k: usize,
    opts: &KmeansOpts,
    pool: Option<&ThreadPool>,
) -> KmeansResult {
    assert!(d > 0 && flat.len() % d == 0, "flat must be (s, d)");
    let s = flat.len() / d;
    assert!(s > 0, "empty input");
    let k = k.min(s); // cannot have more clusters than points
    let mut rng = Rng::new(opts.seed);

    let mut centers = kmeanspp_init(flat, s, d, k, &mut rng, pool);
    let mut codes = vec![0u32; s];
    #[allow(unused_assignments)]
    let mut prev_mse = f64::INFINITY;
    let mut iters = 0;

    for it in 0..opts.max_iters {
        iters = it + 1;
        // Assignment sweep (parallel over fixed sub-vector chunks).
        let mse = assign_sweep(flat, &centers, d, k, &mut codes, pool);

        // Update step.
        let mut sums = vec![0.0f64; k * d];
        let mut counts = vec![0usize; k];
        for g in 0..s {
            let c = codes[g] as usize;
            counts[c] += 1;
            for j in 0..d {
                sums[c * d + j] += flat[g * d + j] as f64;
            }
        }
        for c in 0..k {
            if counts[c] == 0 {
                // Re-seed empty cluster at a random point (standard fix).
                let g = rng.below(s);
                centers[c * d..(c + 1) * d].copy_from_slice(&flat[g * d..(g + 1) * d]);
            } else {
                for j in 0..d {
                    centers[c * d + j] = (sums[c * d + j] / counts[c] as f64) as f32;
                }
            }
        }

        if prev_mse.is_finite() && (prev_mse - mse) / prev_mse.max(1e-30) < opts.tol {
            break;
        }
        prev_mse = mse;
    }

    // Final assignment against the final centers.
    let mse = assign_sweep(flat, &centers, d, k, &mut codes, pool);
    KmeansResult {
        codebook: Codebook::new(k, d, centers),
        codes,
        mse,
        iterations: iters,
    }
}

/// Nearest-center assignment over fixed chunks.  Each chunk writes a
/// disjoint `codes` range and its own error-partial slot; the partials
/// are reduced in chunk order, making the f64 sum independent of worker
/// scheduling.
///
/// §Perf: at `d >= ops::PRUNE_MIN_D` the inner scan is the norm-seeded
/// pruned scan (`ops::nearest_pruned`, per-center squared norms computed
/// once per sweep) — bit-identical to the naive scan retained for
/// smaller `d` (codes, argmin tie-breaks, and the f32 distance bits
/// feeding the chunk-ordered f64 partials), so the dispatch never
/// changes results.
fn assign_sweep(
    flat: &[f32],
    centers: &[f32],
    d: usize,
    k: usize,
    codes: &mut [u32],
    pool: Option<&ThreadPool>,
) -> f64 {
    let s = codes.len();
    if s == 0 {
        return 0.0;
    }
    let nchunks = s.div_ceil(CHUNK);
    let mut errs = vec![0.0f64; nchunks];
    let prune = ops::prunes_at(d);
    let norms: Vec<f32> = if prune {
        centers.chunks_exact(d).map(|c| ops::dot(c, c)).collect()
    } else {
        Vec::new()
    };

    let kernel = |start: usize, end: usize, codes_chunk: &mut [u32]| -> f64 {
        let mut local = 0.0f64;
        for (off, code) in codes_chunk.iter_mut().enumerate() {
            let g = start + off;
            let sub = &flat[g * d..(g + 1) * d];
            let (best, best_d) = if prune {
                ops::nearest_pruned(sub, centers, &norms)
            } else {
                let mut best = 0usize;
                let mut best_d = f32::INFINITY;
                for c in 0..k {
                    let dist = ops::sq_dist(sub, &centers[c * d..(c + 1) * d]);
                    if dist < best_d {
                        best_d = dist;
                        best = c;
                    }
                }
                (best, best_d)
            };
            *code = best as u32;
            local += best_d as f64;
        }
        local
    };

    match pool {
        Some(pool) if pool.threads() > 1 && s > CHUNK => {
            let codes_ptr = SyncPtr::new(codes);
            let errs_ptr = SyncPtr::new(&mut errs);
            pool.note_read(flat);
            pool.note_read(centers);
            pool.parallel_for(s, CHUNK, |start, end| {
                // SAFETY: parallel_for ranges are disjoint.
                let chunk = unsafe { codes_ptr.slice(start, end - start) };
                let e = kernel(start, end, chunk);
                // SAFETY: each chunk index maps to a unique error slot.
                unsafe { errs_ptr.slice(start / CHUNK, 1)[0] = e };
            })
            .expect("k-means assignment sweep worker panicked");
        }
        _ => {
            let mut start = 0;
            while start < s {
                let end = (start + CHUNK).min(s);
                errs[start / CHUNK] = kernel(start, end, &mut codes[start..end]);
                start = end;
            }
        }
    }
    let total: f64 = errs.iter().sum();
    total / flat.len() as f64
}

/// k-means++ seeding: D^2-weighted center selection.  The per-point
/// distance refresh after each new center is the `O(s * k * d)` half of
/// the cost, so it runs over the same fixed-chunk schedule; the partial
/// totals reduce in chunk order and the weighted pick stays serial.
fn kmeanspp_init(
    flat: &[f32],
    s: usize,
    d: usize,
    k: usize,
    rng: &mut Rng,
    pool: Option<&ThreadPool>,
) -> Vec<f32> {
    let mut centers = Vec::with_capacity(k * d);
    let first = rng.below(s);
    centers.extend_from_slice(&flat[first * d..(first + 1) * d]);
    let mut dist2 = vec![f32::INFINITY; s];
    let nchunks = s.div_ceil(CHUNK);
    let mut partials = vec![0.0f64; nchunks];
    for c in 1..k {
        let last = &centers[(c - 1) * d..c * d];

        let kernel = |start: usize, end: usize, d2_chunk: &mut [f32]| -> f64 {
            let mut local = 0.0f64;
            for (off, d2) in d2_chunk.iter_mut().enumerate() {
                let g = start + off;
                let dd = ops::sq_dist(&flat[g * d..(g + 1) * d], last);
                if dd < *d2 {
                    *d2 = dd;
                }
                local += *d2 as f64;
            }
            local
        };

        match pool {
            Some(pool) if pool.threads() > 1 && s > CHUNK => {
                let dist_ptr = SyncPtr::new(&mut dist2);
                let part_ptr = SyncPtr::new(&mut partials);
                pool.parallel_for(s, CHUNK, |start, end| {
                    // SAFETY: parallel_for chunk ranges are disjoint.
                    let d2 = unsafe { dist_ptr.slice(start, end - start) };
                    let p = kernel(start, end, d2);
                    // SAFETY: each chunk index maps to a unique partial slot.
                    unsafe { part_ptr.slice(start / CHUNK, 1)[0] = p };
                })
                .expect("k-means++ distance sweep worker panicked");
            }
            _ => {
                let mut start = 0;
                while start < s {
                    let end = (start + CHUNK).min(s);
                    partials[start / CHUNK] = kernel(start, end, &mut dist2[start..end]);
                    start = end;
                }
            }
        }
        let total: f64 = partials.iter().sum();

        let pick = if total <= 0.0 {
            rng.below(s)
        } else {
            let mut target = rng.uniform() * total;
            let mut chosen = s - 1;
            for g in 0..s {
                target -= dist2[g] as f64;
                if target <= 0.0 {
                    chosen = g;
                    break;
                }
            }
            chosen
        };
        centers.extend_from_slice(&flat[pick * d..(pick + 1) * d]);
    }
    centers
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Three tight clusters -> k-means with k=3 must hit ~0 error.
    #[test]
    fn recovers_separated_clusters() {
        let mut rng = Rng::new(5);
        let centers = [[0.0f32, 0.0], [10.0, 0.0], [0.0, 10.0]];
        let mut flat = Vec::new();
        for i in 0..300 {
            let c = centers[i % 3];
            flat.push(c[0] + rng.normal_f32(0.0, 0.05));
            flat.push(c[1] + rng.normal_f32(0.0, 0.05));
        }
        let res = kmeans(&flat, 2, 3, &KmeansOpts::default());
        assert!(res.mse < 0.01, "mse {}", res.mse);
        // All three clusters used.
        let used: std::collections::HashSet<_> = res.codes.iter().collect();
        assert_eq!(used.len(), 3);
    }

    #[test]
    fn mse_decreases_with_k() {
        let mut rng = Rng::new(6);
        let mut flat = vec![0.0f32; 2 * 500];
        rng.fill_normal(&mut flat);
        let m2 = kmeans(&flat, 2, 2, &KmeansOpts::default()).mse;
        let m16 = kmeans(&flat, 2, 16, &KmeansOpts::default()).mse;
        let m64 = kmeans(&flat, 2, 64, &KmeansOpts::default()).mse;
        assert!(m2 > m16 && m16 > m64, "{m2} > {m16} > {m64}");
    }

    #[test]
    fn k_clamped_to_points() {
        let flat = [1.0f32, 2.0, 3.0, 4.0]; // 2 points, d=2
        let res = kmeans(&flat, 2, 100, &KmeansOpts::default());
        assert_eq!(res.codebook.k, 2);
        assert!(res.mse < 1e-12);
    }

    #[test]
    fn deterministic_given_seed() {
        let mut rng = Rng::new(7);
        let mut flat = vec![0.0f32; 4 * 200];
        rng.fill_normal(&mut flat);
        let a = kmeans(&flat, 4, 8, &KmeansOpts::default());
        let b = kmeans(&flat, 4, 8, &KmeansOpts::default());
        assert_eq!(a.codes, b.codes);
        assert_eq!(a.codebook.words, b.codebook.words);
    }

    /// At d >= PRUNE_MIN_D the sweep dispatches to the pruned scan; the
    /// final assignments must still be exact brute-force nearest centers
    /// (first index on ties).
    #[test]
    fn pruned_sweep_assignments_match_brute_force() {
        let mut rng = Rng::new(9);
        let d = 8;
        let mut flat = vec![0.0f32; d * 300];
        rng.fill_normal(&mut flat);
        let res = kmeans(&flat, d, 10, &KmeansOpts::default());
        for g in 0..300 {
            let sub = &flat[g * d..(g + 1) * d];
            let mut best = 0usize;
            let mut best_d = f32::INFINITY;
            for c in 0..res.codebook.k {
                let dist = ops::sq_dist(sub, res.codebook.word(c));
                if dist < best_d {
                    best_d = dist;
                    best = c;
                }
            }
            assert_eq!(res.codes[g], best as u32, "group {g}");
        }
    }

    #[test]
    fn serial_and_parallel_agree_bit_for_bit() {
        let mut rng = Rng::new(8);
        let mut flat = vec![0.0f32; 3 * 700];
        rng.fill_normal(&mut flat);
        let serial = kmeans(
            &flat,
            3,
            12,
            &KmeansOpts {
                threads: 1,
                ..Default::default()
            },
        );
        for threads in [2usize, 5] {
            let par = kmeans(
                &flat,
                3,
                12,
                &KmeansOpts {
                    threads,
                    ..Default::default()
                },
            );
            assert_eq!(serial.codes, par.codes, "threads={threads}");
            assert_eq!(serial.codebook.words, par.codebook.words);
            assert_eq!(serial.mse.to_bits(), par.mse.to_bits());
            assert_eq!(serial.iterations, par.iterations);
        }
    }
}
