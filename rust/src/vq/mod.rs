//! Vector-quantization substrate (pure Rust, host-side).
//!
//! Everything the universal-codebook story needs outside the AOT graphs:
//!
//! * [`codebook`] — the codebook type, storage accounting (Table 1's `C`
//!   column) and hard decode.
//! * [`kde`]      — §4.1's kernel-density-estimation sampler that creates
//!   the universal codebook from multi-network weight pools.
//! * [`kmeans`]   — k-means (Lloyd + k-means++ init), the per-layer-VQ
//!   baseline (DeepCompression/DKM family) and the special-layer
//!   codebooks of §5.
//! * [`assign`]   — Eq. 5 candidate search (Euclidean / cosine / random —
//!   Table 7) and Eq. 7 ratio-logit initialization.
//! * [`ratios`]   — softmax-ratio math + PNC freeze bookkeeping shared
//!   with the coordinator.
//! * [`pack`]     — bit-packing of assignment streams into the compressed
//!   on-disk/ROM format ([`pack::StagedCodes`]: one stream per residual
//!   stage), with the compression-rate arithmetic of §3.1.
//! * [`simd`]     — runtime-dispatched explicit-SIMD arms (AVX2 / NEON /
//!   scalar, `VQ4ALL_SIMD` override) for the wide-row gather and the
//!   lane-order pruned distance scan, with the exactness argument that
//!   keeps every arm bit-identical to its scalar reference.

pub mod assign;
pub mod codebook;
pub mod kde;
pub mod kmeans;
pub mod pack;
pub mod ratios;
pub mod simd;

pub use assign::{candidates, AssignInit, Utilization};
pub use codebook::{Codebook, StagedEncode};
pub use kde::KdeSampler;
pub use kmeans::kmeans;
pub use pack::{
    pack_codes, pack_codes_reference, unpack_codes, unpack_codes_into, unpack_codes_with,
    unpack_one, unpack_range, PackedCodes, StagedCodes,
};
pub use simd::SimdLevel;
