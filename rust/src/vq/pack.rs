//! Bit-packing of assignment streams — the compressed network format.
//!
//! §3.1: assignments cost `(o*i/d) * log2(k)` bits.  This module packs a
//! `u32` code stream at an arbitrary bit width (1..=32) into a dense
//! little-endian bit stream, unpacks it, and provides the compression
//! accounting used by every table (model bytes, ratio vs f32).
//!
//! The pack format is also what the serving path decodes on the fly
//! (`serving::switchsim`), so unpack speed is a §Perf hot path: the bulk
//! unpack chunks **on code boundaries** (a chunk starting at code `i`
//! begins at bit offset `i * bits`, independent of the worker count), so
//! the pooled path is bit-identical to serial at every thread count.
//!
//! §Perf (word-level unpack): [`unpack_range`] no longer walks the
//! stream bit by bit.  A code at index `i` occupies bits
//! `[i*bits, (i+1)*bits)` of the little-endian stream; with `bits <= 32`
//! and a byte offset of at most 7, those bits always sit inside the 8
//! bytes starting at `i*bits/8`:
//!
//! ```text
//! data:   ... [b] [b+1] [b+2] [b+3] [b+4] [b+5] [b+6] [b+7] ...
//!              └─────────── u64 window (LE load) ──────────┘
//! code i:      ····xxxxx·······   = (window >> (bitpos & 7)) & mask
//! ```
//!
//! so one load + one shift + one mask replaces the per-bit loop.
//! Byte-aligned widths (8/16/32) read whole lanes, sub-byte powers of
//! two (1/2/4) read one byte, and the stream-end tail (where an 8-byte
//! window would run past the buffer) reads through a zero-padded stack
//! copy.  The original scalar loop is retained as
//! [`unpack_range_reference`] — the property-test ground truth and the
//! legacy side of the `unpack_wordwise` bench row.
//!
//! §Perf (word-level pack): [`pack_codes`] is the encode-side mirror — a
//! `u64` shift accumulator collects codes LSB-first and flushes 32 bits
//! at a time as a little-endian lane, so one shift + one OR per code and
//! one 4-byte store per 32 accumulated bits replace the per-bit store
//! loop.  The original bit-at-a-time packer is retained as
//! [`pack_codes_reference`] (ground truth + the legacy side of the
//! `pack_wordwise` bench row); both produce byte-identical streams.
//!
//! §Residual stages: [`StagedCodes`] lifts the one-stream assumption —
//! a compressed net carries one `PackedCodes` per residual stage, all
//! indexing the *same* universal codebook (decode sums one gather per
//! stage; ROM budget unchanged).  `stages == 1` is byte-identical to the
//! legacy single-stream format, so existing artifacts keep working.

use crate::util::threadpool::{SyncPtr, ThreadPool};

/// Codes per scheduling chunk for the parallel bulk unpack.  Fixed —
/// never derived from the worker count — and every chunk starts at a
/// known bit offset (`start * bits`), which is what makes the
/// decomposition deterministic.
const UNPACK_CHUNK: usize = 1024;

/// A packed code stream.
#[derive(Clone, Debug, PartialEq)]
pub struct PackedCodes {
    pub bits: u32,
    pub count: usize,
    pub data: Vec<u8>,
}

/// Pack `codes` at `bits` per entry (LSB-first within the stream).
///
/// §Perf: word-level kernel.  A `u64` accumulator holds fewer than 32
/// pending bits at every loop top, so `acc |= code << nbits` never
/// shifts past bit 63 (`nbits <= 31`, `bits <= 32`); once 32 or more
/// bits are pending, the low lane is stored as 4 little-endian bytes.
/// In-bounds by the stream-length invariant `out * 8 + nbits ==` bits
/// consumed `<= total_bits <= data.len() * 8`: `nbits >= 32` implies
/// `out + 4 <= data.len()`.  The tail flush writes the remaining
/// `nbits < 32` bits a byte at a time (acc's bits above `nbits` are
/// zero, so the last partial byte matches the zero-padded allocation).
/// Byte-identical to the retained [`pack_codes_reference`] — proven at
/// widths 1..=32 with tail-heavy counts in the tests below and in
/// `rust/tests/prop_substrate.rs`.
pub fn pack_codes(codes: &[u32], bits: u32) -> PackedCodes {
    assert!((1..=32).contains(&bits), "bits must be 1..=32");
    let mask = if bits == 32 { u32::MAX } else { (1u32 << bits) - 1 };
    for (i, &c) in codes.iter().enumerate() {
        assert!(c <= mask, "code {c} at {i} exceeds {bits} bits");
    }
    let bw = bits as usize;
    let total_bits = codes.len() * bw;
    let mut data = vec![0u8; total_bits.div_ceil(8)];
    let mut acc = 0u64;
    let mut nbits = 0usize;
    let mut out = 0usize;
    for &c in codes {
        acc |= (c as u64) << nbits;
        nbits += bw;
        if nbits >= 32 {
            data[out..out + 4].copy_from_slice(&(acc as u32).to_le_bytes());
            out += 4;
            acc >>= 32;
            nbits -= 32;
        }
    }
    while nbits > 0 {
        data[out] = acc as u8;
        acc >>= 8;
        out += 1;
        nbits = nbits.saturating_sub(8);
    }
    PackedCodes {
        bits,
        count: codes.len(),
        data,
    }
}

/// The retained scalar reference for [`pack_codes`]: the original
/// byte/bit-at-a-time store loop.  Kept as the ground truth the
/// word-level packer is property-tested against
/// (`rust/tests/prop_substrate.rs`) and as the legacy side of the
/// `pack_wordwise` hotpath bench row.
pub fn pack_codes_reference(codes: &[u32], bits: u32) -> PackedCodes {
    assert!((1..=32).contains(&bits), "bits must be 1..=32");
    let mask = if bits == 32 { u32::MAX } else { (1u32 << bits) - 1 };
    for (i, &c) in codes.iter().enumerate() {
        assert!(c <= mask, "code {c} at {i} exceeds {bits} bits");
    }
    let total_bits = codes.len() * bits as usize;
    let mut data = vec![0u8; total_bits.div_ceil(8)];
    let mut bitpos = 0usize;
    for &c in codes {
        let mut v = c as u64;
        let mut remaining = bits as usize;
        while remaining > 0 {
            let byte = bitpos / 8;
            let off = bitpos % 8;
            let take = (8 - off).min(remaining);
            data[byte] |= (((v & ((1u64 << take) - 1)) as u8) << off) as u8;
            v >>= take;
            bitpos += take;
            remaining -= take;
        }
    }
    PackedCodes {
        bits,
        count: codes.len(),
        data,
    }
}

/// Unpack codes `[start, end)` into `dst` (`dst.len() == end - start`).
/// This is the chunk kernel of the parallel bulk unpack and the serving
/// batched-decode row reader: because the stream is fixed-width, the
/// read starts at the statically known bit offset `start * bits`.
///
/// §Perf: dispatches on the width — byte-aligned widths (8/16/32) read
/// whole little-endian lanes, sub-byte power-of-two widths (1/2/4) never
/// straddle a byte so a single byte load suffices, and every other width
/// runs the branchless word-level kernel (one `u64` window load + one
/// shift + one mask per code).  Every path is bit-identical to the
/// retained scalar reference [`unpack_range_reference`] — unpack is
/// exact integer work, and the property suite proves it at widths
/// 1..=32 over arbitrary windows and stream-end tails.
pub fn unpack_range(p: &PackedCodes, start: usize, end: usize, dst: &mut [u32]) {
    assert!(start <= end && end <= p.count, "range [{start}, {end}) out of {}", p.count);
    assert_eq!(dst.len(), end - start, "unpack_range dst size");
    match p.bits {
        8 => {
            for (i, slot) in dst.iter_mut().enumerate() {
                *slot = p.data[start + i] as u32;
            }
        }
        16 => {
            for (i, slot) in dst.iter_mut().enumerate() {
                let b = (start + i) * 2;
                *slot = u16::from_le_bytes([p.data[b], p.data[b + 1]]) as u32;
            }
        }
        32 => {
            for (i, slot) in dst.iter_mut().enumerate() {
                let b = (start + i) * 4;
                let w = [p.data[b], p.data[b + 1], p.data[b + 2], p.data[b + 3]];
                *slot = u32::from_le_bytes(w);
            }
        }
        1 | 2 | 4 => {
            // Sub-byte powers of two divide 8: a code never straddles a
            // byte boundary, so one byte load + shift + mask per code.
            let bits = p.bits as usize;
            let mask = (1u32 << bits) - 1;
            let per_byte = 8 / bits;
            for (i, slot) in dst.iter_mut().enumerate() {
                let idx = start + i;
                *slot = ((p.data[idx / per_byte] as u32) >> ((idx % per_byte) * bits)) & mask;
            }
        }
        _ => unpack_range_wordwise(p, start, end, dst),
    }
}

/// Load the little-endian `u64` window starting at byte `byte`,
/// zero-padding past the stream end — the tail-safe load shared by
/// [`unpack_one`] and the wordwise kernel's tail loop.  Callers
/// guarantee `byte < data.len()` (the code's own bits are in range;
/// only window padding is ever synthetic).
#[inline]
fn load_window(data: &[u8], byte: usize) -> u64 {
    if byte + 8 <= data.len() {
        u64::from_le_bytes(data[byte..byte + 8].try_into().expect("8-byte window"))
    } else {
        let mut buf = [0u8; 8];
        buf[..data.len() - byte].copy_from_slice(&data[byte..]);
        u64::from_le_bytes(buf)
    }
}

/// General-width word-level kernel: each code's `bits` (< 32 here, so at
/// most 7 + 31 = 38 window bits) live inside the 8 bytes starting at
/// `bitpos / 8`, so one little-endian `u64` load, one shift, and one
/// mask produce the code — no per-bit loop, no branches in the main
/// body.  The range is split so the main loop's 8-byte load is always in
/// bounds; the few codes near the stream end read through the
/// zero-padded [`load_window`] instead.
fn unpack_range_wordwise(p: &PackedCodes, start: usize, end: usize, dst: &mut [u32]) {
    let bits = p.bits as usize;
    debug_assert!(bits < 32 && !matches!(bits, 1 | 2 | 4 | 8 | 16));
    let mask = (1u64 << bits) - 1;
    let data = &p.data;
    // Largest code index whose 8-byte window fits: idx*bits/8 + 8 <= len
    // <=> idx*bits < (len - 7) * 8  <=>  idx < ceil((len - 7) * 8 / bits).
    let fit = if data.len() >= 8 {
        ((data.len() - 7) * 8).div_ceil(bits).min(end).max(start)
    } else {
        start
    };
    let mut bitpos = start * bits;
    for slot in dst[..fit - start].iter_mut() {
        let byte = bitpos >> 3;
        let w = u64::from_le_bytes(data[byte..byte + 8].try_into().expect("8-byte window"));
        *slot = ((w >> (bitpos & 7)) & mask) as u32;
        bitpos += bits;
    }
    for slot in dst[fit - start..].iter_mut() {
        let w = load_window(data, bitpos >> 3);
        *slot = ((w >> (bitpos & 7)) & mask) as u32;
        bitpos += bits;
    }
}

/// The retained scalar reference for [`unpack_range`]: the original
/// byte/bit-at-a-time loop.  Kept as the ground truth the word-level
/// kernels are property-tested against (`rust/tests/prop_substrate.rs`)
/// and as the legacy side of the `unpack_wordwise` hotpath bench row.
pub fn unpack_range_reference(p: &PackedCodes, start: usize, end: usize, dst: &mut [u32]) {
    assert!(start <= end && end <= p.count, "range [{start}, {end}) out of {}", p.count);
    assert_eq!(dst.len(), end - start, "unpack_range dst size");
    let bits = p.bits as usize;
    let mut bitpos = start * bits;
    for slot in dst.iter_mut() {
        let mut v = 0u64;
        let mut got = 0usize;
        while got < bits {
            let byte = bitpos / 8;
            let off = bitpos % 8;
            let take = (8 - off).min(bits - got);
            let chunk = ((p.data[byte] >> off) as u64) & ((1u64 << take) - 1);
            v |= chunk << got;
            got += take;
            bitpos += take;
        }
        *slot = v as u32;
    }
}

/// Unpack back into `u32` codes.  Serial entry point — identical output
/// to [`unpack_codes_with`] at any thread count.
pub fn unpack_codes(p: &PackedCodes) -> Vec<u32> {
    unpack_codes_with(p, None)
}

/// Bulk unpack with the stream split over fixed chunks of codes, each
/// chunk starting at its known bit offset.  Chunks write disjoint output
/// ranges and read the shared immutable byte stream, so the result is
/// bit-identical to the serial path regardless of scheduling.
pub fn unpack_codes_with(p: &PackedCodes, pool: Option<&ThreadPool>) -> Vec<u32> {
    let mut out = vec![0u32; p.count];
    unpack_codes_into(p, &mut out, pool);
    out
}

/// Bulk unpack into a caller-provided buffer (`dst.len() == p.count`) —
/// the allocation-free twin of [`unpack_codes_with`] used by the serving
/// engine's streaming decode plane.  Same chunking, same determinism
/// contract.
pub fn unpack_codes_into(p: &PackedCodes, dst: &mut [u32], pool: Option<&ThreadPool>) {
    assert_eq!(dst.len(), p.count, "unpack_codes_into dst size");
    match pool {
        Some(tp) if tp.threads() > 1 && p.count > UNPACK_CHUNK => {
            let out_ptr = SyncPtr::new(dst);
            tp.parallel_for(p.count, UNPACK_CHUNK, |start, end| {
                // SAFETY: parallel_for ranges are disjoint code ranges.
                let chunk = unsafe { out_ptr.slice(start, end - start) };
                unpack_range(p, start, end, chunk);
            })
            .expect("unpack worker panicked");
        }
        _ => unpack_range(p, 0, p.count, dst),
    }
}

/// Unpack a single code at index `i` without touching the rest — the
/// serving random-access path.  One bounds check and one word load: this
/// no longer routes through [`unpack_range`], whose range/size asserts
/// (and width dispatch) are pure overhead for a single code.
pub fn unpack_one(p: &PackedCodes, i: usize) -> u32 {
    assert!(i < p.count, "unpack_one: index {i} out of {}", p.count);
    let bits = p.bits as usize;
    let mask = if p.bits == 32 { u64::from(u32::MAX) } else { (1u64 << bits) - 1 };
    let bitpos = i * bits;
    let w = load_window(&p.data, bitpos >> 3);
    ((w >> (bitpos & 7)) & mask) as u32
}

/// FNV-1a, 64-bit — the repo-native integrity hash for packed code
/// streams.  Dependency-free, byte-order independent (the caller feeds
/// little-endian encodings), and fast enough to verify a hosted net's
/// streams on demand.
fn fnv1a64(seed: u64, bytes: &[u8]) -> u64 {
    const FNV_PRIME: u64 = 0x0000_0100_0000_01B3;
    let mut h = seed;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

/// FNV-1a offset basis (the standard 64-bit seed).
const FNV_OFFSET: u64 = 0xCBF2_9CE4_8422_2325;

impl PackedCodes {
    pub fn bytes(&self) -> usize {
        self.data.len()
    }

    /// Integrity checksum of this stream: FNV-1a over the width, the
    /// code count, and every packed byte (all little-endian), so a
    /// flipped bit anywhere — header or payload — changes the sum.
    pub fn checksum(&self) -> u64 {
        let h = fnv1a64(FNV_OFFSET, &self.bits.to_le_bytes());
        let h = fnv1a64(h, &(self.count as u64).to_le_bytes());
        fnv1a64(h, &self.data)
    }
}

/// A residual multi-stage code stream: one [`PackedCodes`] per stage,
/// every stage indexing the *same* universal codebook (global indices —
/// no per-stage codebooks, so the ROM budget is unchanged; arXiv
/// 1907.05686's residual scheme on the paper's §3.2 built-in-ROM
/// premise).  Stage 0 carries the nearest-codeword assignment of the
/// weights; stage `s >= 1` carries the assignment of the residual left
/// by stages `0..s`.  Decode is a sum of per-stage gathers
/// ([`crate::vq::Codebook::decode_staged_packed_into`]).
///
/// All stages have the same code count (one code per weight group per
/// stage).  `stages == 1` is byte-identical to the legacy single-stream
/// format: [`StagedCodes::single`] wraps a `PackedCodes` without
/// touching a byte, and `stage(0)` hands it back as-is.
#[derive(Clone, Debug, PartialEq)]
pub struct StagedCodes {
    stages: Vec<PackedCodes>,
}

impl StagedCodes {
    /// Wrap a legacy single-stage stream.  Byte-identical to the input:
    /// no re-pack, no copy beyond the move.
    pub fn single(p: PackedCodes) -> Self {
        StagedCodes { stages: vec![p] }
    }

    /// Build from per-stage streams.  Every stage must carry the same
    /// code count (one code per group per stage); stage widths may
    /// differ (matched-total-bit sweeps pack narrower stages).
    pub fn new(stages: Vec<PackedCodes>) -> Self {
        assert!(!stages.is_empty(), "StagedCodes needs at least one stage");
        let count = stages[0].count;
        for (s, p) in stages.iter().enumerate() {
            assert_eq!(p.count, count, "stage {s} code-count mismatch");
        }
        StagedCodes { stages }
    }

    /// Number of residual stages (>= 1).
    pub fn stages(&self) -> usize {
        self.stages.len()
    }

    /// The packed stream of stage `s`.
    pub fn stage(&self, s: usize) -> &PackedCodes {
        &self.stages[s]
    }

    /// Mutable per-stage access — the chaos-suite corruption hook
    /// (`Shard::corrupt_net_byte`), compiled only under `fault-inject`
    /// so the default API keeps the streams immutable after packing.
    #[cfg(feature = "fault-inject")]
    pub fn stage_mut(&mut self, s: usize) -> &mut PackedCodes {
        &mut self.stages[s]
    }

    /// All per-stage streams, stage-major.
    pub fn stage_streams(&self) -> &[PackedCodes] {
        &self.stages
    }

    /// Codes per stage (groups in the quantized scope).
    pub fn count(&self) -> usize {
        self.stages[0].count
    }

    /// Total packed bytes across stages — the `assign_bytes` of the
    /// compression accounting.
    pub fn bytes(&self) -> usize {
        self.stages.iter().map(|p| p.bytes()).sum()
    }

    /// Index bits per group summed over stages — the matched-total-bits
    /// axis of the stages sweep.
    pub fn total_bits(&self) -> u32 {
        self.stages.iter().map(|p| p.bits).sum()
    }

    /// Per-stage integrity checksums ([`PackedCodes::checksum`], stage
    /// order).  Stamped into artifact manifests at pack time and into
    /// the serving plane at hosting time; re-verified on demand by
    /// [`StagedCodes::verify_checksums`] / `Engine::verify_hosted`.
    pub fn checksums(&self) -> Vec<u64> {
        self.stages.iter().map(|p| p.checksum()).collect()
    }

    /// Recompute every stage's checksum and compare against `expected`
    /// (stage order).  A mismatch names the stage and both sums — the
    /// caller quarantines the net instead of serving garbage.
    pub fn verify_checksums(&self, expected: &[u64]) -> anyhow::Result<()> {
        anyhow::ensure!(
            expected.len() == self.stages.len(),
            "checksum count {} != {} stages",
            expected.len(),
            self.stages.len()
        );
        for (s, (p, &want)) in self.stages.iter().zip(expected).enumerate() {
            let got = p.checksum();
            anyhow::ensure!(
                got == want,
                "stage {s} checksum mismatch: stream {got:#018x} != expected {want:#018x} \
                 (corrupted packed bytes)"
            );
        }
        Ok(())
    }
}

/// Compression accounting for one network (§3.1 / Table 1 "Rate").
#[derive(Clone, Copy, Debug, Default)]
pub struct SizeReport {
    /// f32 bytes of the original compressed-scope weights.
    pub float_bytes: usize,
    /// Packed assignment bytes.
    pub assign_bytes: usize,
    /// Codebook bytes *attributed to this network* (0 for the universal
    /// codebook amortized into ROM; k*d*4 for per-layer baselines).
    pub codebook_bytes: usize,
    /// Uncompressed (excluded-layer + bias/norm) bytes kept at f32.
    pub other_bytes: usize,
}

impl SizeReport {
    pub fn compressed_total(&self) -> usize {
        self.assign_bytes + self.codebook_bytes + self.other_bytes
    }

    pub fn original_total(&self) -> usize {
        self.float_bytes + self.other_bytes
    }

    /// Whole-model compression ratio.
    pub fn ratio(&self) -> f64 {
        self.original_total() as f64 / self.compressed_total().max(1) as f64
    }

    /// Ratio over the compressed scope only (Table 3's per-layer rate).
    pub fn scope_ratio(&self) -> f64 {
        self.float_bytes as f64 / (self.assign_bytes + self.codebook_bytes).max(1) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn roundtrip_all_bitwidths() {
        let mut rng = Rng::new(1);
        for bits in 1..=32u32 {
            let mask = if bits == 32 { u32::MAX } else { (1 << bits) - 1 };
            let codes: Vec<u32> = (0..257).map(|_| (rng.next_u64() as u32) & mask).collect();
            let p = pack_codes(&codes, bits);
            assert_eq!(unpack_codes(&p), codes, "bits={bits}");
            // Random access agrees with bulk unpack.
            for &i in &[0usize, 1, 100, 256] {
                assert_eq!(unpack_one(&p, i), codes[i], "bits={bits} i={i}");
            }
        }
    }

    #[test]
    fn packed_size_is_tight() {
        let codes = vec![1u32; 100];
        let p = pack_codes(&codes, 3);
        assert_eq!(p.bytes(), (100usize * 3).div_ceil(8));
    }

    #[test]
    #[should_panic(expected = "exceeds")]
    fn rejects_out_of_range_codes() {
        pack_codes(&[8], 3);
    }

    #[test]
    fn unpack_range_reads_arbitrary_windows() {
        let mut rng = Rng::new(9);
        for bits in [3u32, 5, 7, 13] {
            let mask = (1u32 << bits) - 1;
            let codes: Vec<u32> = (0..301).map(|_| (rng.next_u64() as u32) & mask).collect();
            let p = pack_codes(&codes, bits);
            for (start, end) in [(0usize, 301usize), (17, 191), (300, 301), (0, 0)] {
                let mut dst = vec![0u32; end - start];
                unpack_range(&p, start, end, &mut dst);
                assert_eq!(dst, codes[start..end], "bits={bits} [{start}, {end})");
            }
        }
    }

    #[test]
    fn unpack_codes_into_matches_alloc_path() {
        let mut rng = Rng::new(11);
        let pool = ThreadPool::new(3);
        for bits in [1u32, 5, 13] {
            let mask = (1u32 << bits) - 1;
            let codes: Vec<u32> = (0..UNPACK_CHUNK * 2 + 5)
                .map(|_| (rng.next_u64() as u32) & mask)
                .collect();
            let p = pack_codes(&codes, bits);
            let mut dst = vec![0u32; p.count];
            unpack_codes_into(&p, &mut dst, None);
            assert_eq!(dst, codes, "serial bits={bits}");
            dst.fill(0);
            unpack_codes_into(&p, &mut dst, Some(&pool));
            assert_eq!(dst, codes, "pooled bits={bits}");
        }
    }

    #[test]
    #[should_panic(expected = "dst size")]
    fn unpack_codes_into_checks_dst_len() {
        let p = pack_codes(&[1u32, 2, 3], 2);
        let mut dst = vec![0u32; 2];
        unpack_codes_into(&p, &mut dst, None);
    }

    /// The pooled bulk unpack must split (count > UNPACK_CHUNK) and still
    /// produce the exact serial stream at awkward non-byte widths.
    #[test]
    fn parallel_unpack_bit_identical_to_serial() {
        let mut rng = Rng::new(7);
        let pool = ThreadPool::new(4);
        for bits in [1u32, 3, 5, 7, 13, 31] {
            let mask = if bits == 32 { u32::MAX } else { (1 << bits) - 1 };
            let codes: Vec<u32> = (0..UNPACK_CHUNK * 3 + 17)
                .map(|_| (rng.next_u64() as u32) & mask)
                .collect();
            let p = pack_codes(&codes, bits);
            assert_eq!(unpack_codes_with(&p, Some(&pool)), codes, "bits={bits}");
        }
    }

    /// The word-level dispatch must agree with the retained scalar
    /// reference at every width — including the byte-aligned and
    /// power-of-two fast paths — on windows that end at the stream tail
    /// (where the 8-byte load would run past the buffer).
    #[test]
    fn wordwise_unpack_matches_reference_at_every_width() {
        let mut rng = Rng::new(21);
        for bits in 1..=32u32 {
            let mask = if bits == 32 { u32::MAX } else { (1 << bits) - 1 };
            for count in [1usize, 2, 7, 65, 300] {
                let codes: Vec<u32> =
                    (0..count).map(|_| (rng.next_u64() as u32) & mask).collect();
                let p = pack_codes(&codes, bits);
                let windows = [
                    (0usize, count),
                    (count / 3, count),
                    (count.saturating_sub(2), count),
                    (0, count / 2),
                ];
                for (start, end) in windows {
                    let mut fast = vec![0u32; end - start];
                    let mut slow = vec![0u32; end - start];
                    unpack_range(&p, start, end, &mut fast);
                    unpack_range_reference(&p, start, end, &mut slow);
                    assert_eq!(fast, slow, "bits={bits} count={count} [{start}, {end})");
                }
            }
        }
    }

    /// Regression for the `unpack_one` fast path: single-code reads at
    /// the stream end exercise the zero-padded tail load, and every
    /// index must agree with the packed values at tail-heavy counts.
    #[test]
    fn unpack_one_direct_word_load_is_tail_safe() {
        let mut rng = Rng::new(22);
        for bits in [1u32, 3, 5, 8, 13, 16, 31, 32] {
            let mask = if bits == 32 { u32::MAX } else { (1 << bits) - 1 };
            for count in 1..=9usize {
                let codes: Vec<u32> =
                    (0..count).map(|_| (rng.next_u64() as u32) & mask).collect();
                let p = pack_codes(&codes, bits);
                for (i, &c) in codes.iter().enumerate() {
                    assert_eq!(unpack_one(&p, i), c, "bits={bits} count={count} i={i}");
                }
            }
        }
    }

    #[test]
    #[should_panic(expected = "out of")]
    fn unpack_one_rejects_out_of_range_index() {
        let p = pack_codes(&[1u32, 2], 3);
        unpack_one(&p, 2);
    }

    /// The word-level packer must produce the exact byte stream of the
    /// retained bit-at-a-time reference at every width, including
    /// tail-heavy counts where the final flush writes partial bytes.
    #[test]
    fn wordwise_pack_matches_reference_at_every_width() {
        let mut rng = Rng::new(23);
        for bits in 1..=32u32 {
            let mask = if bits == 32 { u32::MAX } else { (1 << bits) - 1 };
            for count in [0usize, 1, 2, 7, 65, 300] {
                let codes: Vec<u32> =
                    (0..count).map(|_| (rng.next_u64() as u32) & mask).collect();
                let fast = pack_codes(&codes, bits);
                let slow = pack_codes_reference(&codes, bits);
                assert_eq!(fast, slow, "bits={bits} count={count}");
            }
        }
    }

    #[test]
    #[should_panic(expected = "exceeds")]
    fn wordwise_pack_rejects_out_of_range_codes() {
        pack_codes_reference(&[8], 3);
    }

    /// `StagedCodes::single` is byte-identical to the wrapped legacy
    /// stream — the stages == 1 compatibility contract.
    #[test]
    fn staged_single_is_byte_identical_to_legacy() {
        let codes = vec![3u32, 1, 4, 1, 5];
        let p = pack_codes(&codes, 3);
        let staged = StagedCodes::single(p.clone());
        assert_eq!(staged.stages(), 1);
        assert_eq!(staged.stage(0), &p);
        assert_eq!(staged.count(), 5);
        assert_eq!(staged.bytes(), p.bytes());
        assert_eq!(staged.total_bits(), 3);
    }

    #[test]
    fn staged_accounting_sums_stages() {
        let s0 = pack_codes(&[1u32, 2, 3], 5);
        let s1 = pack_codes(&[0u32, 1, 0], 2);
        let staged = StagedCodes::new(vec![s0.clone(), s1.clone()]);
        assert_eq!(staged.stages(), 2);
        assert_eq!(staged.count(), 3);
        assert_eq!(staged.bytes(), s0.bytes() + s1.bytes());
        assert_eq!(staged.total_bits(), 7);
        assert_eq!(staged.stage_streams(), &[s0, s1]);
    }

    #[test]
    #[should_panic(expected = "at least one stage")]
    fn staged_rejects_empty() {
        StagedCodes::new(vec![]);
    }

    #[test]
    #[should_panic(expected = "count mismatch")]
    fn staged_rejects_mismatched_counts() {
        StagedCodes::new(vec![pack_codes(&[1u32, 2], 3), pack_codes(&[1u32], 3)]);
    }

    #[test]
    fn checksum_detects_any_corruption() {
        let codes = vec![3u32, 1, 4, 1, 5, 9, 2, 6];
        let p = pack_codes(&codes, 5);
        let base = p.checksum();
        assert_eq!(p.checksum(), base, "checksum is deterministic");
        // Every single-bit flip in the payload changes the sum.
        for byte in 0..p.data.len() {
            for bit in 0..8 {
                let mut bad = p.clone();
                bad.data[byte] ^= 1 << bit;
                assert_ne!(bad.checksum(), base, "flip at {byte}:{bit} undetected");
            }
        }
        // Header fields are covered too.
        let mut bad = p.clone();
        bad.bits = 6;
        assert_ne!(bad.checksum(), base);
        let mut bad = p.clone();
        bad.count = 7;
        assert_ne!(bad.checksum(), base);
    }

    #[test]
    fn staged_checksums_verify_and_name_the_bad_stage() {
        let s0 = pack_codes(&[1u32, 2, 3], 5);
        let s1 = pack_codes(&[0u32, 1, 0], 2);
        let staged = StagedCodes::new(vec![s0, s1]);
        let sums = staged.checksums();
        assert_eq!(sums.len(), 2);
        staged.verify_checksums(&sums).unwrap();
        // Wrong stage-1 sum is caught and attributed.
        let mut bad = sums.clone();
        bad[1] ^= 1;
        let err = staged.verify_checksums(&bad).unwrap_err().to_string();
        assert!(err.contains("stage 1"), "got: {err}");
        assert!(err.contains("mismatch"), "got: {err}");
        // Wrong cardinality is caught before any comparison.
        let err = staged.verify_checksums(&sums[..1]).unwrap_err().to_string();
        assert!(err.contains("checksum count"), "got: {err}");
    }

    #[test]
    fn size_report_ratios() {
        // 1M weights at f32 = 4MB scope; 2-bit codes = 250KB; universal
        // codebook -> 0 attributed bytes; 40KB others.
        let r = SizeReport {
            float_bytes: 4_000_000,
            assign_bytes: 250_000,
            codebook_bytes: 0,
            other_bytes: 40_000,
        };
        assert!((r.ratio() - (4_040_000.0 / 290_000.0)).abs() < 1e-9);
        assert!((r.scope_ratio() - 16.0).abs() < 1e-9);
    }
}
