//! Softmax-ratio math + PNC freeze bookkeeping (Eq. 6 / Eq. 14),
//! host-side mirror of `vqlayers.effective_ratios`.
//!
//! The coordinator reads the logits `z` back from the device every
//! `pnc_interval` steps and uses these helpers to decide freezes; the
//! same code backs the Figure-3 largest-ratio histogram and the Table-5
//! optimal-assignment-index analysis.

use crate::tensor::ops;
use crate::util::threadpool::{SyncPtr, ThreadPool};

/// Groups per scheduling chunk for the PNC scan sweep (fixed, so the
/// decomposition never depends on the worker count).
const SCAN_CHUNK: usize = 512;

/// Per-group PNC state: 0 = free, 1 = frozen to `frozen_idx`.
#[derive(Clone, Debug, Default)]
pub struct FreezeState {
    pub frozen: Vec<f32>,     // (s,) in {0.0, 1.0}
    pub frozen_idx: Vec<i32>, // (s,) candidate slot
}

impl FreezeState {
    pub fn new(s: usize) -> Self {
        FreezeState {
            frozen: vec![0.0; s],
            frozen_idx: vec![0; s],
        }
    }

    pub fn num_frozen(&self) -> usize {
        self.frozen.iter().filter(|&&f| f > 0.5).count()
    }

    pub fn is_frozen(&self, g: usize) -> bool {
        self.frozen[g] > 0.5
    }

    pub fn all_frozen(&self) -> bool {
        self.num_frozen() == self.frozen.len()
    }

    /// Freeze group `g` to candidate slot `m`.  Idempotent; never
    /// *unfreezes* (the PNC invariant — property-tested).
    pub fn freeze(&mut self, g: usize, m: usize) {
        if !self.is_frozen(g) {
            self.frozen[g] = 1.0;
            self.frozen_idx[g] = m as i32;
        }
    }
}

/// Effective ratios (Eq. 6 + Eq. 14): softmax rows for free groups,
/// one-hot rows for frozen groups.  `z` is `(s, n)`.
pub fn effective_ratios(z: &[f32], n: usize, fs: &FreezeState) -> Vec<f32> {
    let s = z.len() / n;
    assert_eq!(z.len(), s * n);
    assert_eq!(fs.frozen.len(), s);
    let mut r = z.to_vec();
    ops::softmax_rows(&mut r, s, n);
    for g in 0..s {
        if fs.is_frozen(g) {
            let row = &mut r[g * n..(g + 1) * n];
            row.fill(0.0);
            row[fs.frozen_idx[g] as usize] = 1.0;
        }
    }
    r
}

/// Max softmax ratio + its slot for one logit row.  `softmax(z)[argmax]`
/// equals `1 / sum(exp(z - max))` with the sum accumulated in row order —
/// the exact arithmetic `ops::softmax_rows` performs, without
/// materializing the full softmax.
#[inline]
fn row_max_ratio(row: &[f32]) -> (f32, usize) {
    let m = ops::argmax(row);
    let max = row[m];
    let mut sum = 0.0f32;
    for &v in row {
        sum += (v - max).exp();
    }
    (1.0 / sum, m)
}

/// Max ratio + its slot per group (the PNC scan input).  Serial entry
/// point — identical output to [`max_ratios_with`] at any thread count.
pub fn max_ratios(z: &[f32], n: usize) -> Vec<(f32, usize)> {
    max_ratios_with(z, n, None)
}

/// Max ratio + slot per group, with the row sweep spread over fixed
/// chunks of groups.  Rows are independent, so the output is identical
/// to the serial path regardless of scheduling.
pub fn max_ratios_with(z: &[f32], n: usize, pool: Option<&ThreadPool>) -> Vec<(f32, usize)> {
    let s = z.len() / n;
    assert_eq!(z.len(), s * n);
    let mut out = vec![(0.0f32, 0usize); s];

    match pool {
        Some(tp) if tp.threads() > 1 && s > SCAN_CHUNK => {
            let out_ptr = SyncPtr::new(&mut out);
            tp.parallel_for(s, SCAN_CHUNK, |start, end| {
                // SAFETY: disjoint group ranges per chunk.
                let dst = unsafe { out_ptr.slice(start, end - start) };
                for (off, slot) in dst.iter_mut().enumerate() {
                    let g = start + off;
                    *slot = row_max_ratio(&z[g * n..(g + 1) * n]);
                }
            })
            .expect("PNC ratio sweep worker panicked");
        }
        _ => {
            for (g, slot) in out.iter_mut().enumerate() {
                *slot = row_max_ratio(&z[g * n..(g + 1) * n]);
            }
        }
    }
    out
}

/// Final hard codes (Algorithm 1 output): frozen slot or argmax slot,
/// mapped through the candidate table.  `assign` is `(s, n)` codeword ids.
pub fn hard_codes(z: &[f32], assign: &[u32], n: usize, fs: &FreezeState) -> Vec<u32> {
    let s = z.len() / n;
    assert_eq!(assign.len(), s * n);
    let mr = max_ratios(z, n);
    (0..s)
        .map(|g| {
            let slot = if fs.is_frozen(g) {
                fs.frozen_idx[g] as usize
            } else {
                mr[g].1
            };
            assign[g * n + slot]
        })
        .collect()
}

/// Eq. 13's construction-gap: `sum ||R C[A] - C[A[argmax R]]||^2` between
/// the soft reconstruction and the hard collapse — the quantity PNC keeps
/// small.  Returns the summed squared error.
pub fn collapse_gap(
    z: &[f32],
    assign: &[u32],
    n: usize,
    fs: &FreezeState,
    cb: &super::codebook::Codebook,
) -> f64 {
    let s = z.len() / n;
    let r = effective_ratios(z, n, fs);
    let mut soft = vec![0.0f32; s * cb.d];
    cb.decode_weighted(assign, &r, n, &mut soft);
    let codes = hard_codes(z, assign, n, fs);
    let hard = cb.decode_vec(&codes);
    soft.iter()
        .zip(&hard)
        .map(|(&a, &b)| {
            let d = (a - b) as f64;
            d * d
        })
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::vq::codebook::Codebook;

    #[test]
    fn freeze_is_sticky() {
        let mut fs = FreezeState::new(3);
        fs.freeze(1, 2);
        assert!(fs.is_frozen(1));
        assert_eq!(fs.frozen_idx[1], 2);
        fs.freeze(1, 0); // second freeze must not change the slot
        assert_eq!(fs.frozen_idx[1], 2);
        assert_eq!(fs.num_frozen(), 1);
    }

    #[test]
    fn effective_ratios_mixes_soft_and_onehot() {
        let z = vec![0.0, 0.0, 5.0, 0.0]; // 2 groups, n=2
        let mut fs = FreezeState::new(2);
        fs.freeze(0, 1);
        let r = effective_ratios(&z, 2, &fs);
        assert_eq!(&r[0..2], &[0.0, 1.0], "frozen row is one-hot");
        assert!(r[2] > 0.99, "free row is softmax");
        assert!((r[2] + r[3] - 1.0).abs() < 1e-6);
    }

    #[test]
    fn hard_codes_respects_freeze_and_argmax() {
        let z = vec![3.0, 0.0, 0.0, 3.0];
        let assign = vec![10u32, 11, 20, 21];
        let mut fs = FreezeState::new(2);
        fs.freeze(0, 1); // frozen to slot 1 even though argmax is slot 0
        let codes = hard_codes(&z, &assign, 2, &fs);
        assert_eq!(codes, vec![11, 21]);
    }

    #[test]
    fn max_ratios_matches_explicit_softmax_and_parallel_path() {
        let mut rng = crate::util::rng::Rng::new(13);
        let n = 6;
        let s = 1500; // > SCAN_CHUNK so the pooled path really splits
        let mut z = vec![0.0f32; s * n];
        rng.fill_normal(&mut z);
        // Reference: full softmax + argmax.
        let mut soft = z.clone();
        ops::softmax_rows(&mut soft, s, n);
        let serial = max_ratios(&z, n);
        for g in 0..s {
            let row = &soft[g * n..(g + 1) * n];
            let m = ops::argmax(row);
            assert_eq!(serial[g].1, m, "slot mismatch at group {g}");
            assert_eq!(
                serial[g].0.to_bits(),
                row[m].to_bits(),
                "ratio mismatch at group {g}"
            );
        }
        let tp = ThreadPool::new(4);
        let par = max_ratios_with(&z, n, Some(&tp));
        assert_eq!(serial, par);
    }

    #[test]
    fn collapse_gap_zero_when_onehot() {
        let cb = Codebook::new(2, 2, vec![0., 0., 1., 1.]);
        let z = vec![20.0, -20.0]; // softmax ~ one-hot on slot 0
        let assign = vec![1u32, 0];
        let fs = FreezeState::new(1);
        let gap = collapse_gap(&z, &assign, 2, &fs, &cb);
        assert!(gap < 1e-9, "gap {gap}");
    }

    #[test]
    fn collapse_gap_positive_when_soft() {
        let cb = Codebook::new(2, 2, vec![0., 0., 1., 1.]);
        let z = vec![0.0, 0.0]; // 50/50 mix -> soft = (0.5, 0.5), hard = (0,0)
        let assign = vec![1u32, 0];
        let fs = FreezeState::new(1);
        let gap = collapse_gap(&z, &assign, 2, &fs, &cb);
        assert!((gap - 0.5).abs() < 1e-6, "(0.5)^2 * 2 dims = 0.5, got {gap}");
    }
}
