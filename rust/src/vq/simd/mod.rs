//! Runtime-dispatched explicit-SIMD kernels for the two hottest serving
//! cores (per VQ-LLM, arXiv 2503.02236 — codebook-centric kernel
//! specialization): the wide-row (`d >= LANES`) gather / gather-accumulate
//! behind `Codebook::decode_packed_into` / `decode_staged_packed_into`,
//! and the lane-summed squared-distance scan behind
//! `tensor::ops::sq_dist` / `sq_dist_pruned` / `nearest_pruned`.
//!
//! §Dispatch.  [`SimdLevel`] names the arms: `Scalar` (the portable
//! lane-order kernels in this file — also the property-test references),
//! `Avx2` (x86_64, 8 f32 lanes, gated on `is_x86_feature_detected!`) and
//! `Neon` (aarch64 baseline, two 4-lane accumulators).  [`active`]
//! resolves the process-wide default once: `VQ4ALL_SIMD=scalar|avx2|neon`
//! forces an arm (panicking loudly if the host can't run it — CI uses
//! this to prove which arm ran), `auto`/unset picks [`best`].  Every
//! kernel also takes the level as an explicit argument so property tests
//! and benches can exercise *all* available arms in one process; hot
//! call sites probe once per sweep, not per element.
//!
//! §Exactness (the lane-tree summation order).  f32 addition is not
//! associative, so a vectorized sum only stays bit-identical if scalar
//! and vector code commit to the *same* association.  For slices with
//! `len >= LANES` the canonical squared-distance accumulation is defined
//! to be:
//!
//! * eight independent lane accumulators, `lane[j]` summing the squared
//!   errors of elements `j, j+8, j+16, ...` in index order (a ragged
//!   tail of `r < 8` elements adds into lanes `0..r`);
//! * the fixed combine tree [`combine8`]:
//!   `s_j = lane[j] + lane[j+4]` (j = 0..4), then
//!   `(s_0 + s_2) + (s_1 + s_3)`.
//!
//! That order is exactly what the vector arms compute with plain
//! mul+add: one 8-lane `vaddps` (or two 4-lane `vaddq_f32`) per block
//! *is* the per-lane scalar recurrence, and the standard horizontal
//! reduction (high half + low half, then pairwise) *is* the combine
//! tree.  No FMA anywhere — a fused multiply-add rounds once where
//! mul+add rounds twice, which would change bits.
//!
//! §Exactness (the pruned bail).  `sq_dist_pruned_lanes*` returns
//! `Some(S)` iff the canonical full sum `S <= limit`, else `None` — the
//! final check runs on the completed sum, so the *observable result is a
//! pure function of `(a, b, limit)`, independent of where intermediate
//! bail checks sit*.  Intermediate bails (after each 8-lane block) are
//! sound because every term is nonnegative and f32 round-to-nearest is
//! monotone: each lane accumulator is nondecreasing over blocks, and
//! [`combine8`] is monotone in every argument, so a partial combined sum
//! that already exceeds `limit` proves the final sum does too.
//! Conversely a candidate whose full sum is `<= limit` can never bail
//! early.  The scalar reference and both vector arms therefore agree on
//! accepted/rejected *and* on the returned bits, whatever their check
//! cadence — here all arms check once per block, which also preserves
//! the pruning win.
//!
//! §Gather exactness is trivial: the gather is a pure row copy (vector
//! loads/stores move the same bytes), and the gather-accumulate performs
//! one independent f32 add per element — lane-wise `vaddps` is exactly
//! the scalar per-element add, no reassociation anywhere.
//!
//! Audit: this module and its arch submodules are on the PR-6
//! `UNSAFE_ALLOWLIST`; every `unsafe` carries a SAFETY justification,
//! and the four `*_reference` kernels are manifest-mapped to the
//! `simd_gather` / `simd_scan` bench rows.

use std::sync::OnceLock;

#[cfg(target_arch = "x86_64")]
mod x86;

#[cfg(target_arch = "aarch64")]
mod neon;

/// f32 lanes per block — the width of the canonical lane-order
/// accumulation and the minimum `d` for the wide-row gather arms.
pub const LANES: usize = 8;

/// One dispatch arm.  `Scalar` is always available; the vector arms are
/// per-arch (see [`SimdLevel::available`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SimdLevel {
    /// Portable lane-order kernels (the `*_reference` twins).
    Scalar,
    /// x86_64 AVX2: one 8-lane f32 accumulator.
    Avx2,
    /// aarch64 NEON: two 4-lane f32 accumulators.
    Neon,
}

impl SimdLevel {
    pub fn name(self) -> &'static str {
        match self {
            SimdLevel::Scalar => "scalar",
            SimdLevel::Avx2 => "avx2",
            SimdLevel::Neon => "neon",
        }
    }

    /// Can this arm run on the current host?
    pub fn available(self) -> bool {
        match self {
            SimdLevel::Scalar => true,
            SimdLevel::Avx2 => avx2_detected(),
            SimdLevel::Neon => cfg!(target_arch = "aarch64"),
        }
    }
}

#[cfg(target_arch = "x86_64")]
fn avx2_detected() -> bool {
    std::arch::is_x86_feature_detected!("avx2")
}

#[cfg(not(target_arch = "x86_64"))]
fn avx2_detected() -> bool {
    false
}

/// The best arm this host can run: AVX2 > NEON > scalar.
pub fn best() -> SimdLevel {
    if SimdLevel::Avx2.available() {
        SimdLevel::Avx2
    } else if SimdLevel::Neon.available() {
        SimdLevel::Neon
    } else {
        SimdLevel::Scalar
    }
}

/// Parse a `VQ4ALL_SIMD` value: `Ok(None)` means auto (use [`best`]),
/// `Ok(Some(level))` a forced arm, `Err` an unknown spelling.
pub fn parse_level(s: &str) -> Result<Option<SimdLevel>, String> {
    match s.trim().to_ascii_lowercase().as_str() {
        "" | "auto" => Ok(None),
        "scalar" => Ok(Some(SimdLevel::Scalar)),
        "avx2" => Ok(Some(SimdLevel::Avx2)),
        "neon" => Ok(Some(SimdLevel::Neon)),
        other => Err(format!(
            "unknown VQ4ALL_SIMD value {other:?} (want scalar|avx2|neon|auto)"
        )),
    }
}

/// The process-wide default arm, resolved once: `VQ4ALL_SIMD` forces an
/// arm (panicking if the host can't run it or the value is unknown —
/// a silent fallback would defeat the CI dispatch matrix), otherwise
/// [`best`].  Hot sweeps probe this once and thread the level through
/// their inner loops.
pub fn active() -> SimdLevel {
    static ACTIVE: OnceLock<SimdLevel> = OnceLock::new();
    *ACTIVE.get_or_init(|| {
        let raw = std::env::var("VQ4ALL_SIMD").unwrap_or_default();
        match parse_level(&raw) {
            Ok(None) => best(),
            Ok(Some(level)) => {
                assert!(
                    level.available(),
                    "VQ4ALL_SIMD={} forced, but this host cannot run that arm \
                     (arch {}, avx2 {})",
                    level.name(),
                    std::env::consts::ARCH,
                    SimdLevel::Avx2.available(),
                );
                level
            }
            Err(msg) => panic!("{msg}"),
        }
    })
}

/// One-line dispatch report — printed by the `simd_probe` binary and the
/// serving engine at construction; the CI `simd-matrix` job greps it to
/// prove which arm actually ran.
pub fn probe_line() -> String {
    format!(
        "vq4all-simd: active={} best={} env={} avx2={} neon={} arch={}",
        active().name(),
        best().name(),
        std::env::var("VQ4ALL_SIMD").unwrap_or_else(|_| "auto".to_string()),
        SimdLevel::Avx2.available(),
        SimdLevel::Neon.available(),
        std::env::consts::ARCH,
    )
}

/// The fixed combine tree of the canonical lane-order sum (see the
/// module docs): monotone in every argument, and exactly the horizontal
/// reduction the vector arms perform in-register.
#[inline]
fn combine8(l: &[f32; LANES]) -> f32 {
    let s0 = l[0] + l[4];
    let s1 = l[1] + l[5];
    let s2 = l[2] + l[6];
    let s3 = l[3] + l[7];
    (s0 + s2) + (s1 + s3)
}

// ---------------------------------------------------------------------------
// Scalar lane-order references (the canonical definitions)
// ---------------------------------------------------------------------------

/// Canonical lane-order squared distance (see module docs) — the scalar
/// reference the vector arms are proven bit-identical to, and the
/// definition `tensor::ops::sq_dist` dispatches to at `len >= LANES`.
/// Legacy side of the `simd_scan` bench row.
pub fn sq_dist_lanes_reference(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let mut lanes = [0.0f32; LANES];
    let mut i = 0;
    while i + LANES <= n {
        for j in 0..LANES {
            let e = a[i + j] - b[i + j];
            lanes[j] += e * e;
        }
        i += LANES;
    }
    let mut j = 0;
    while i + j < n {
        let e = a[i + j] - b[i + j];
        lanes[j] += e * e;
        j += 1;
    }
    combine8(&lanes)
}

/// Canonical lane-order pruned squared distance: `Some(S)` iff the full
/// canonical sum `S <= limit` (strict bail, matching
/// `tensor::ops::sq_dist_pruned` semantics), checking the combined
/// running sum after each 8-lane block.  The scalar reference of the
/// `simd_scan` row; see the module docs for why the bail cadence cannot
/// change the observable result.
pub fn sq_dist_pruned_lanes_reference(a: &[f32], b: &[f32], limit: f32) -> Option<f32> {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let mut lanes = [0.0f32; LANES];
    let mut i = 0;
    while i + LANES <= n {
        for j in 0..LANES {
            let e = a[i + j] - b[i + j];
            lanes[j] += e * e;
        }
        i += LANES;
        if i + LANES <= n && combine8(&lanes) > limit {
            return None;
        }
    }
    let mut j = 0;
    while i + j < n {
        let e = a[i + j] - b[i + j];
        lanes[j] += e * e;
        j += 1;
    }
    let s = combine8(&lanes);
    if s > limit {
        None
    } else {
        Some(s)
    }
}

/// Scalar wide-row gather: `dst[row] = words[codes[row]]` for rows of
/// `d >= LANES` f32s — the reference twin of the vector copy arms and
/// the legacy side of the `simd_gather` bench row.  (Small `d` keeps the
/// monomorphized kernels in `vq::codebook`.)
pub fn gather_rows_reference(words: &[f32], codes: &[u32], d: usize, dst: &mut [f32]) {
    debug_assert!(d >= 1);
    debug_assert_eq!(dst.len(), codes.len() * d);
    for (row, &c) in dst.chunks_exact_mut(d).zip(codes) {
        row.copy_from_slice(&words[c as usize * d..(c as usize + 1) * d]);
    }
}

/// Scalar wide-row gather-accumulate: `dst[row] += words[codes[row]]`,
/// one independent f32 add per element in `j` order — the reference twin
/// of the vector accumulate arms (`simd_gather` row).
pub fn gather_rows_add_reference(words: &[f32], codes: &[u32], d: usize, dst: &mut [f32]) {
    debug_assert!(d >= 1);
    debug_assert_eq!(dst.len(), codes.len() * d);
    for (row, &c) in dst.chunks_exact_mut(d).zip(codes) {
        let w = &words[c as usize * d..(c as usize + 1) * d];
        for (slot, wj) in row.iter_mut().zip(w) {
            *slot += wj;
        }
    }
}

// ---------------------------------------------------------------------------
// Dispatched entry points
// ---------------------------------------------------------------------------
//
// Each wrapper re-checks availability in its match guard, so selecting a
// vector arm is locally proven sound — an unavailable level silently
// degrades to the scalar reference (unreachable from `active`/`best`,
// which never hand out unavailable arms).

/// Lane-order squared distance on the given arm.  Bit-identical to
/// [`sq_dist_lanes_reference`] at every level (property-tested per arm).
pub fn sq_dist_lanes(level: SimdLevel, a: &[f32], b: &[f32]) -> f32 {
    match level {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: the guard just confirmed AVX2 support on this host.
        SimdLevel::Avx2 if SimdLevel::Avx2.available() => unsafe { x86::sq_dist_lanes_avx2(a, b) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON is baseline on aarch64 targets.
        SimdLevel::Neon => unsafe { neon::sq_dist_lanes_neon(a, b) },
        _ => sq_dist_lanes_reference(a, b),
    }
}

/// Lane-order pruned squared distance on the given arm.  Identical
/// accepted/rejected decisions and `Some` bits as
/// [`sq_dist_pruned_lanes_reference`] (see module docs).
pub fn sq_dist_pruned_lanes(level: SimdLevel, a: &[f32], b: &[f32], limit: f32) -> Option<f32> {
    match level {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: the guard just confirmed AVX2 support on this host.
        SimdLevel::Avx2 if SimdLevel::Avx2.available() => unsafe {
            x86::sq_dist_pruned_lanes_avx2(a, b, limit)
        },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON is baseline on aarch64 targets.
        SimdLevel::Neon => unsafe { neon::sq_dist_pruned_lanes_neon(a, b, limit) },
        _ => sq_dist_pruned_lanes_reference(a, b, limit),
    }
}

/// Wide-row gather on the given arm (pure row copies — trivially
/// bit-identical to [`gather_rows_reference`]).
pub fn gather_rows(level: SimdLevel, words: &[f32], codes: &[u32], d: usize, dst: &mut [f32]) {
    match level {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: the guard just confirmed AVX2 support on this host.
        SimdLevel::Avx2 if SimdLevel::Avx2.available() => unsafe {
            x86::gather_rows_avx2(words, codes, d, dst)
        },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON is baseline on aarch64 targets.
        SimdLevel::Neon => unsafe { neon::gather_rows_neon(words, codes, d, dst) },
        _ => gather_rows_reference(words, codes, d, dst),
    }
}

/// Wide-row gather-accumulate on the given arm (independent per-element
/// f32 adds — bit-identical to [`gather_rows_add_reference`]).
pub fn gather_rows_add(level: SimdLevel, words: &[f32], codes: &[u32], d: usize, dst: &mut [f32]) {
    match level {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: the guard just confirmed AVX2 support on this host.
        SimdLevel::Avx2 if SimdLevel::Avx2.available() => unsafe {
            x86::gather_rows_add_avx2(words, codes, d, dst)
        },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON is baseline on aarch64 targets.
        SimdLevel::Neon => unsafe { neon::gather_rows_add_neon(words, codes, d, dst) },
        _ => gather_rows_add_reference(words, codes, d, dst),
    }
}

/// Every arm the current host can run (scalar first) — the iteration
/// set of the per-arm property tests and the audit of the dispatch
/// matrix.
pub fn available_levels() -> Vec<SimdLevel> {
    let mut levels = vec![SimdLevel::Scalar];
    for l in [SimdLevel::Avx2, SimdLevel::Neon] {
        if l.available() {
            levels.push(l);
        }
    }
    levels
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn combine8_is_the_documented_tree() {
        // Values chosen so every alternative association changes bits.
        let l = [1.0e8f32, 1.0, 3.0e-8, 7.5, 2.0e8, 0.25, 9.0e-8, 1.5];
        let s0 = l[0] + l[4];
        let s1 = l[1] + l[5];
        let s2 = l[2] + l[6];
        let s3 = l[3] + l[7];
        let want = (s0 + s2) + (s1 + s3);
        assert_eq!(combine8(&l).to_bits(), want.to_bits());
    }

    #[test]
    fn parse_level_spellings() {
        assert_eq!(parse_level("auto"), Ok(None));
        assert_eq!(parse_level(""), Ok(None));
        assert_eq!(parse_level("Scalar"), Ok(Some(SimdLevel::Scalar)));
        assert_eq!(parse_level(" avx2 "), Ok(Some(SimdLevel::Avx2)));
        assert_eq!(parse_level("NEON"), Ok(Some(SimdLevel::Neon)));
        assert!(parse_level("sse9").is_err());
    }

    #[test]
    fn probe_reports_an_available_active_arm() {
        let a = active();
        assert!(a.available(), "active arm must be runnable");
        assert!(best().available());
        let line = probe_line();
        assert!(line.contains(&format!("active={}", a.name())), "{line}");
    }

    #[test]
    fn scalar_lane_reference_handles_tails() {
        let mut rng = Rng::new(11);
        for n in [8usize, 9, 12, 15, 16, 17, 31, 32, 40] {
            let mut a = vec![0.0f32; n];
            let mut b = vec![0.0f32; n];
            rng.fill_normal(&mut a);
            rng.fill_normal(&mut b);
            // Recompute by hand with explicit lane bookkeeping.
            let mut lanes = [0.0f32; LANES];
            for (i, (x, y)) in a.iter().zip(&b).enumerate() {
                let e = x - y;
                lanes[i % LANES] += e * e;
            }
            let want = combine8(&lanes);
            assert_eq!(sq_dist_lanes_reference(&a, &b).to_bits(), want.to_bits(), "n={n}");
        }
    }

    #[test]
    fn pruned_lane_reference_is_a_pure_function_of_the_full_sum() {
        let mut rng = Rng::new(13);
        for n in [8usize, 12, 16, 24, 33] {
            let mut a = vec![0.0f32; n];
            let mut b = vec![0.0f32; n];
            rng.fill_normal(&mut a);
            rng.fill_normal(&mut b);
            let full = sq_dist_lanes_reference(&a, &b);
            // Generous limit: exact bits back.
            let got = sq_dist_pruned_lanes_reference(&a, &b, f32::INFINITY).unwrap();
            assert_eq!(got.to_bits(), full.to_bits(), "n={n}");
            // Limit exactly the sum: strict bail keeps it alive.
            let got = sq_dist_pruned_lanes_reference(&a, &b, full).unwrap();
            assert_eq!(got.to_bits(), full.to_bits(), "n={n}");
            // Any limit below the sum rejects.
            assert_eq!(sq_dist_pruned_lanes_reference(&a, &b, full * 0.999), None);
            assert_eq!(sq_dist_pruned_lanes_reference(&a, &b, 0.0), None);
        }
    }

    #[test]
    fn every_available_arm_matches_the_scalar_reference() {
        let mut rng = Rng::new(17);
        for level in available_levels() {
            for n in [8usize, 9, 12, 16, 23, 32, 65] {
                let mut a = vec![0.0f32; n];
                let mut b = vec![0.0f32; n];
                rng.fill_normal(&mut a);
                rng.fill_normal(&mut b);
                let want = sq_dist_lanes_reference(&a, &b);
                let got = sq_dist_lanes(level, &a, &b);
                assert_eq!(got.to_bits(), want.to_bits(), "{} n={n}", level.name());
                for limit in [f32::INFINITY, want, want * 0.999, want * 0.25, 0.0] {
                    let want_p = sq_dist_pruned_lanes_reference(&a, &b, limit);
                    let got_p = sq_dist_pruned_lanes(level, &a, &b, limit);
                    assert_eq!(
                        got_p.map(f32::to_bits),
                        want_p.map(f32::to_bits),
                        "{} n={n} limit={limit}",
                        level.name()
                    );
                }
            }
        }
    }

    #[test]
    fn gather_arms_match_reference_on_ragged_widths() {
        let mut rng = Rng::new(19);
        for level in available_levels() {
            for d in [8usize, 9, 12, 16, 19, 24] {
                let k = 32;
                let mut words = vec![0.0f32; k * d];
                rng.fill_normal(&mut words);
                let codes: Vec<u32> = (0..77).map(|_| rng.below(k) as u32).collect();
                let mut want = vec![0.0f32; codes.len() * d];
                let mut got = vec![0.0f32; codes.len() * d];
                gather_rows_reference(&words, &codes, d, &mut want);
                gather_rows(level, &words, &codes, d, &mut got);
                let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
                assert_eq!(bits(&got), bits(&want), "{} d={d}", level.name());
                gather_rows_add_reference(&words, &codes, d, &mut want);
                gather_rows_add(level, &words, &codes, d, &mut got);
                assert_eq!(bits(&got), bits(&want), "{} d={d} (add)", level.name());
            }
        }
    }
}
