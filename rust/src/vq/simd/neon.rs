//! NEON arms of the `vq::simd` kernels (aarch64 only; NEON is baseline
//! on every aarch64 target, so availability is a compile-time fact).
//!
//! Two 4-lane `float32x4_t` accumulators stand in for the eight scalar
//! lane accumulators of the canonical order (`acc0` holds lanes 0..4,
//! `acc1` lanes 4..8): per block, `vaddq_f32(acc, vmulq_f32(e, e))` is
//! exactly the per-lane scalar recurrence (plain mul + add, never FMA —
//! `vfmaq` would round once where the reference rounds twice).  The
//! horizontal reduction [`hsum8`] is exactly the [`super::combine8`]
//! tree: `vaddq(acc0, acc1)` gives `[s0, s1, s2, s3]`, low+high halves
//! give `[s0+s2, s1+s3]`, and the pairwise add gives `t0 + t1`.  Ragged
//! tails use the same scalar loops as the references.

use std::arch::aarch64::{
    float32x4_t, vadd_f32, vaddq_f32, vdupq_n_f32, vget_high_f32, vget_lane_f32, vget_low_f32,
    vld1q_f32, vmulq_f32, vpadd_f32, vst1q_f32, vsubq_f32,
};

use super::{combine8, LANES};

/// Half a block: the lane count of one NEON vector.
const HALF: usize = 4;

/// Horizontal sum of the two 4-lane accumulators in exactly the
/// [`super::combine8`] association.
///
/// # Safety
/// NEON is baseline on aarch64; this module only compiles there.
#[inline]
unsafe fn hsum8(acc0: float32x4_t, acc1: float32x4_t) -> f32 {
    // Register-only NEON ops, no memory access (bare calls: the body of
    // an unsafe fn, and safe intrinsics on toolchains that mark them so).
    let s = vaddq_f32(acc0, acc1);
    let t = vadd_f32(vget_low_f32(s), vget_high_f32(s));
    vget_lane_f32::<0>(vpadd_f32(t, t))
}

/// Spill both accumulators to the scalar lane array (`acc0` -> lanes
/// 0..4, `acc1` -> lanes 4..8) for tail handling and the final
/// [`super::combine8`].
///
/// # Safety
/// NEON is baseline on aarch64; this module only compiles there.
#[inline]
unsafe fn spill(acc0: float32x4_t, acc1: float32x4_t) -> [f32; LANES] {
    let mut lanes = [0.0f32; LANES];
    // SAFETY: `lanes` holds 8 f32s: both 4-f32 stores are in bounds.
    unsafe {
        vst1q_f32(lanes.as_mut_ptr(), acc0);
        vst1q_f32(lanes.as_mut_ptr().add(HALF), acc1);
    }
    lanes
}

/// NEON twin of [`super::sq_dist_lanes_reference`] — bit-identical by
/// the lane-order argument in the module docs.
///
/// # Safety
/// NEON is baseline on aarch64 (the dispatch arm in
/// [`super::sq_dist_lanes`] only exists for that target).
pub unsafe fn sq_dist_lanes_neon(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    // Register-only initialization (bare call, see hsum8).
    let (mut acc0, mut acc1) = (vdupq_n_f32(0.0), vdupq_n_f32(0.0));
    let mut i = 0;
    while i + LANES <= n {
        // SAFETY: i + 8 <= n == a.len() == b.len(), so all four 4-f32
        // loads are in bounds.
        unsafe {
            let e0 = vsubq_f32(vld1q_f32(a.as_ptr().add(i)), vld1q_f32(b.as_ptr().add(i)));
            let e1 = vsubq_f32(
                vld1q_f32(a.as_ptr().add(i + HALF)),
                vld1q_f32(b.as_ptr().add(i + HALF)),
            );
            acc0 = vaddq_f32(acc0, vmulq_f32(e0, e0));
            acc1 = vaddq_f32(acc1, vmulq_f32(e1, e1));
        }
        i += LANES;
    }
    // SAFETY: NEON is baseline on this target.
    let mut lanes = unsafe { spill(acc0, acc1) };
    let mut j = 0;
    while i + j < n {
        let e = a[i + j] - b[i + j];
        lanes[j] += e * e;
        j += 1;
    }
    combine8(&lanes)
}

/// NEON twin of [`super::sq_dist_pruned_lanes_reference`]: same final
/// sum bits, same accepted/rejected decision, checking once per block
/// like the reference (any cadence is sound — see the parent module).
///
/// # Safety
/// NEON is baseline on aarch64 (see [`super::sq_dist_pruned_lanes`]).
pub unsafe fn sq_dist_pruned_lanes_neon(a: &[f32], b: &[f32], limit: f32) -> Option<f32> {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    // Register-only initialization (bare call, see hsum8).
    let (mut acc0, mut acc1) = (vdupq_n_f32(0.0), vdupq_n_f32(0.0));
    let mut i = 0;
    while i + LANES <= n {
        // SAFETY: i + 8 <= n == a.len() == b.len().
        unsafe {
            let e0 = vsubq_f32(vld1q_f32(a.as_ptr().add(i)), vld1q_f32(b.as_ptr().add(i)));
            let e1 = vsubq_f32(
                vld1q_f32(a.as_ptr().add(i + HALF)),
                vld1q_f32(b.as_ptr().add(i + HALF)),
            );
            acc0 = vaddq_f32(acc0, vmulq_f32(e0, e0));
            acc1 = vaddq_f32(acc1, vmulq_f32(e1, e1));
        }
        i += LANES;
        // SAFETY: register-only horizontal sum.
        if i + LANES <= n && unsafe { hsum8(acc0, acc1) } > limit {
            return None;
        }
    }
    // SAFETY: NEON is baseline on this target.
    let mut lanes = unsafe { spill(acc0, acc1) };
    let mut j = 0;
    while i + j < n {
        let e = a[i + j] - b[i + j];
        lanes[j] += e * e;
        j += 1;
    }
    let s = combine8(&lanes);
    if s > limit {
        None
    } else {
        Some(s)
    }
}

/// NEON twin of [`super::gather_rows_reference`]: 4-lane load/store row
/// copies with a scalar ragged tail — byte-identical to the reference.
///
/// # Safety
/// NEON is baseline on aarch64 (see [`super::gather_rows`]).
pub unsafe fn gather_rows_neon(words: &[f32], codes: &[u32], d: usize, dst: &mut [f32]) {
    debug_assert!(d >= LANES);
    debug_assert_eq!(dst.len(), codes.len() * d);
    for (row, &c) in dst.chunks_exact_mut(d).zip(codes) {
        let w = &words[c as usize * d..(c as usize + 1) * d];
        let mut j = 0;
        while j + HALF <= d {
            // SAFETY: j + 4 <= d == w.len() == row.len().
            unsafe { vst1q_f32(row.as_mut_ptr().add(j), vld1q_f32(w.as_ptr().add(j))) };
            j += HALF;
        }
        while j < d {
            row[j] = w[j];
            j += 1;
        }
    }
}

/// NEON twin of [`super::gather_rows_add_reference`]: lane-wise
/// `vaddq_f32` is exactly one independent f32 add per element, so the
/// result is bit-identical to the scalar accumulate loop.
///
/// # Safety
/// NEON is baseline on aarch64 (see [`super::gather_rows_add`]).
pub unsafe fn gather_rows_add_neon(words: &[f32], codes: &[u32], d: usize, dst: &mut [f32]) {
    debug_assert!(d >= LANES);
    debug_assert_eq!(dst.len(), codes.len() * d);
    for (row, &c) in dst.chunks_exact_mut(d).zip(codes) {
        let w = &words[c as usize * d..(c as usize + 1) * d];
        let mut j = 0;
        while j + HALF <= d {
            // SAFETY: j + 4 <= d == w.len() == row.len().
            unsafe {
                let sum = vaddq_f32(
                    vld1q_f32(row.as_ptr().add(j)),
                    vld1q_f32(w.as_ptr().add(j)),
                );
                vst1q_f32(row.as_mut_ptr().add(j), sum);
            }
            j += HALF;
        }
        while j < d {
            row[j] += w[j];
            j += 1;
        }
    }
}
