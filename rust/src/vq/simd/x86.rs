//! AVX2 arms of the `vq::simd` kernels (x86_64 only; selected at
//! runtime by the [`super::SimdLevel::Avx2`] dispatch guards).
//!
//! Every kernel here implements the canonical lane-order semantics of
//! the scalar references in the parent module, with plain `vmulps` +
//! `vaddps` (never FMA — fusing the multiply-add would round once where
//! the reference rounds twice and change bits).  One 8-lane `__m256`
//! accumulator *is* the eight scalar lane accumulators; the horizontal
//! reduction [`hsum8`] *is* the [`super::combine8`] tree.  Ragged tails
//! (`len % 8`) are handled by the same scalar loops as the references,
//! adding into lanes `0..r` after the vector blocks.
//!
//! All loads/stores are unaligned (`loadu`/`storeu`) on ranges proven
//! in-bounds by slice indexing before the raw-pointer arithmetic.

use std::arch::x86_64::{
    __m256, _mm256_add_ps, _mm256_castps256_ps128, _mm256_extractf128_ps, _mm256_loadu_ps,
    _mm256_mul_ps, _mm256_setzero_ps, _mm256_storeu_ps, _mm256_sub_ps, _mm_add_ps, _mm_add_ss,
    _mm_cvtss_f32, _mm_movehl_ps, _mm_shuffle_ps,
};

use super::{combine8, LANES};

/// Horizontal sum of an 8-lane accumulator in exactly the
/// [`super::combine8`] association: `s = lo + hi` gives
/// `[l0+l4, l1+l5, l2+l6, l3+l7]`, `t = s + movehl(s)` gives
/// `[s0+s2, s1+s3, ..]`, and the final scalar add is `t0 + t1`.
///
/// # Safety
/// Requires AVX2 (callers are themselves `target_feature(avx2)` fns).
#[inline]
#[target_feature(enable = "avx2")]
unsafe fn hsum8(v: __m256) -> f32 {
    let s = _mm_add_ps(_mm256_castps256_ps128(v), _mm256_extractf128_ps::<1>(v));
    let t = _mm_add_ps(s, _mm_movehl_ps(s, s));
    _mm_cvtss_f32(_mm_add_ss(t, _mm_shuffle_ps::<0b01>(t, t)))
}

/// Spill the 8 lanes of `v` to a scalar array (for tail handling and the
/// final [`super::combine8`], which must see the same values the scalar
/// reference accumulates).
///
/// # Safety
/// Requires AVX2 (callers are themselves `target_feature(avx2)` fns).
#[inline]
#[target_feature(enable = "avx2")]
unsafe fn spill(v: __m256) -> [f32; LANES] {
    let mut lanes = [0.0f32; LANES];
    // SAFETY: `lanes` is 8 f32s and `storeu` tolerates any alignment.
    unsafe { _mm256_storeu_ps(lanes.as_mut_ptr(), v) };
    lanes
}

/// AVX2 twin of [`super::sq_dist_lanes_reference`] — bit-identical by
/// the lane-order argument in the module docs.
///
/// # Safety
/// The CPU must support AVX2 (the dispatch guard in
/// [`super::sq_dist_lanes`] checks `is_x86_feature_detected!`).
#[target_feature(enable = "avx2")]
pub unsafe fn sq_dist_lanes_avx2(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let mut acc = _mm256_setzero_ps();
    let mut i = 0;
    while i + LANES <= n {
        // SAFETY: i + 8 <= n == a.len() == b.len(), so both 8-f32 loads
        // are in bounds.
        let (va, vb) = unsafe {
            (
                _mm256_loadu_ps(a.as_ptr().add(i)),
                _mm256_loadu_ps(b.as_ptr().add(i)),
            )
        };
        let e = _mm256_sub_ps(va, vb);
        acc = _mm256_add_ps(acc, _mm256_mul_ps(e, e));
        i += LANES;
    }
    // SAFETY: AVX2 is enabled for this fn.
    let mut lanes = unsafe { spill(acc) };
    let mut j = 0;
    while i + j < n {
        let e = a[i + j] - b[i + j];
        lanes[j] += e * e;
        j += 1;
    }
    combine8(&lanes)
}

/// AVX2 twin of [`super::sq_dist_pruned_lanes_reference`]: same final
/// sum bits, same accepted/rejected decision (the bail is sound at any
/// cadence — see the parent module's exactness argument — and this arm
/// checks once per block like the reference).
///
/// # Safety
/// The CPU must support AVX2 (checked by the dispatch guard in
/// [`super::sq_dist_pruned_lanes`]).
#[target_feature(enable = "avx2")]
pub unsafe fn sq_dist_pruned_lanes_avx2(a: &[f32], b: &[f32], limit: f32) -> Option<f32> {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let mut acc = _mm256_setzero_ps();
    let mut i = 0;
    while i + LANES <= n {
        // SAFETY: i + 8 <= n == a.len() == b.len().
        let (va, vb) = unsafe {
            (
                _mm256_loadu_ps(a.as_ptr().add(i)),
                _mm256_loadu_ps(b.as_ptr().add(i)),
            )
        };
        let e = _mm256_sub_ps(va, vb);
        acc = _mm256_add_ps(acc, _mm256_mul_ps(e, e));
        i += LANES;
        // SAFETY: AVX2 is enabled for this fn.
        if i + LANES <= n && unsafe { hsum8(acc) } > limit {
            return None;
        }
    }
    // SAFETY: AVX2 is enabled for this fn.
    let mut lanes = unsafe { spill(acc) };
    let mut j = 0;
    while i + j < n {
        let e = a[i + j] - b[i + j];
        lanes[j] += e * e;
        j += 1;
    }
    let s = combine8(&lanes);
    if s > limit {
        None
    } else {
        Some(s)
    }
}

/// AVX2 twin of [`super::gather_rows_reference`]: 8-lane unaligned
/// load/store row copies with a scalar ragged tail — byte-identical to
/// the reference `copy_from_slice` by construction.
///
/// # Safety
/// The CPU must support AVX2 (checked by the dispatch guard in
/// [`super::gather_rows`]).
#[target_feature(enable = "avx2")]
pub unsafe fn gather_rows_avx2(words: &[f32], codes: &[u32], d: usize, dst: &mut [f32]) {
    debug_assert!(d >= LANES);
    debug_assert_eq!(dst.len(), codes.len() * d);
    for (row, &c) in dst.chunks_exact_mut(d).zip(codes) {
        let w = &words[c as usize * d..(c as usize + 1) * d];
        let mut j = 0;
        while j + LANES <= d {
            // SAFETY: j + 8 <= d == w.len() == row.len().
            unsafe {
                _mm256_storeu_ps(row.as_mut_ptr().add(j), _mm256_loadu_ps(w.as_ptr().add(j)));
            }
            j += LANES;
        }
        while j < d {
            row[j] = w[j];
            j += 1;
        }
    }
}

/// AVX2 twin of [`super::gather_rows_add_reference`]: lane-wise
/// `vaddps` is exactly one independent f32 add per element, so the
/// result is bit-identical to the scalar accumulate loop.
///
/// # Safety
/// The CPU must support AVX2 (checked by the dispatch guard in
/// [`super::gather_rows_add`]).
#[target_feature(enable = "avx2")]
pub unsafe fn gather_rows_add_avx2(words: &[f32], codes: &[u32], d: usize, dst: &mut [f32]) {
    debug_assert!(d >= LANES);
    debug_assert_eq!(dst.len(), codes.len() * d);
    for (row, &c) in dst.chunks_exact_mut(d).zip(codes) {
        let w = &words[c as usize * d..(c as usize + 1) * d];
        let mut j = 0;
        while j + LANES <= d {
            // SAFETY: j + 8 <= d == w.len() == row.len().
            unsafe {
                let sum = _mm256_add_ps(
                    _mm256_loadu_ps(row.as_ptr().add(j)),
                    _mm256_loadu_ps(w.as_ptr().add(j)),
                );
                _mm256_storeu_ps(row.as_mut_ptr().add(j), sum);
            }
            j += LANES;
        }
        while j < d {
            row[j] += w[j];
            j += 1;
        }
    }
}
