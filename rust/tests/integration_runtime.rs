//! Integration: the PJRT runtime over the real AOT artifacts.
//!
//! Needs `make artifacts` to have run (the Makefile's `test-rs` target
//! guarantees it) **and** a real xla/PJRT build.  When either is missing
//! — notably under the vendored host-only xla stub — every test here
//! skips with a message instead of failing, so `cargo test -q` stays
//! green on artifact-less runners.  Everything uses `mini_mlp`, the
//! smallest zoo member, to keep the suite fast.

use std::path::PathBuf;

use vq4all::coordinator::checkpoint;
use vq4all::coordinator::{Campaign, NetSession, PncScheduler};
use vq4all::runtime::{Manifest, Runtime};
use vq4all::util::config::CampaignConfig;

fn artifacts() -> PathBuf {
    Manifest::default_dir()
}

/// Load the campaign, or `None` (with a visible skip note) when the
/// artifacts or the PJRT runtime are unavailable in this build.
fn campaign(steps: usize) -> Option<Campaign> {
    let cfg = CampaignConfig {
        steps,
        eval_interval: 0,
        ..CampaignConfig::default()
    };
    match Campaign::load(&artifacts(), cfg) {
        Ok(c) => Some(c),
        Err(e) => {
            eprintln!("skipping PJRT integration test (run `make artifacts` with a real xla build): {e}");
            None
        }
    }
}

fn manifest_or_skip() -> Option<Manifest> {
    match Manifest::load(&artifacts()) {
        Ok(m) => Some(m),
        Err(e) => {
            eprintln!("skipping (no artifacts — run `make artifacts`): {e}");
            None
        }
    }
}

#[test]
fn manifest_loads_and_is_consistent() {
    let Some(m) = manifest_or_skip() else { return };
    assert!(!m.networks.is_empty(), "zoo must not be empty");
    assert!(m.config.k.is_power_of_two(), "k must be a power of two");
    for net in &m.networks {
        assert!(net.s_total > 0, "{}: no sub-vector groups", net.name);
        // Every executable's HLO file must exist.
        for (ename, espec) in &net.executables {
            let p = m.path(&espec.hlo);
            assert!(p.exists(), "{}::{ename} HLO missing at {p:?}", net.name);
            assert!(
                !espec.inputs.is_empty() && !espec.outputs.is_empty(),
                "{}::{ename} has an empty signature",
                net.name
            );
        }
        // Layer table must tile s_total exactly.
        let groups: usize = net.layers.iter().map(|l| l.groups).sum();
        assert_eq!(groups, net.s_total, "{}: layer slices don't tile S", net.name);
    }
}

#[test]
fn every_artifact_loads_and_compiles() {
    let Some(m) = manifest_or_skip() else { return };
    let rt = match Runtime::cpu() {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("skipping (no PJRT runtime in this build): {e}");
            return;
        }
    };
    for net in &m.networks {
        for (ename, espec) in &net.executables {
            rt.load(&m.path(&espec.hlo), espec)
                .unwrap_or_else(|e| panic!("{}::{ename}: {e}", net.name));
        }
    }
}

#[test]
fn train_step_decreases_loss_on_mini_mlp() {
    let Some(c) = campaign(12) else { return };
    let mut sess = NetSession::new(&c.rt, &c.manifest, "mini_mlp", &c.codebook).unwrap();
    let mut stream = vq4all::coordinator::calib::CalibStream::new(
        sess.calib_x.clone(),
        sess.calib_y.clone(),
        &sess.net.task,
        sess.net.batch,
        7,
    );
    let mut first = None;
    let mut last = None;
    for _ in 0..12 {
        let batch = stream.next_batch().unwrap();
        let m = sess.train_step(&batch).unwrap();
        assert!(m.iter().all(|x| x.is_finite()), "non-finite loss: {m:?}");
        first.get_or_insert(m[0]);
        last = Some(m[0]);
    }
    // The total loss includes L_r which is driven to 0; over a dozen
    // steps the total must move down.
    assert!(
        last.unwrap() < first.unwrap(),
        "loss did not decrease: {first:?} -> {last:?}"
    );
}

#[test]
fn eval_soft_and_hard_are_close_after_construction() {
    let Some(c) = campaign(40) else { return };
    let res = c.construct("mini_mlp").unwrap();
    assert!(res.float_metric > 0.8, "float net should be accurate");
    assert!(
        (res.soft_metric - res.hard_metric).abs() < 0.2,
        "soft {:.3} vs hard {:.3} diverged",
        res.soft_metric,
        res.hard_metric
    );
    assert!(
        res.hard_metric > res.float_metric - 0.2,
        "hard collapse destroyed the network: {:.3} vs float {:.3}",
        res.hard_metric,
        res.float_metric
    );
    // All codes must index the codebook.
    assert!(res.codes.iter().all(|&c2| (c2 as usize) < c.manifest.config.k));
    assert_eq!(res.codes.len(), c.manifest.network("mini_mlp").unwrap().s_total);
}

#[test]
fn hard_codes_always_come_from_candidate_rows() {
    let Some(c) = campaign(8) else { return };
    let res = c.construct("mini_mlp").unwrap();
    let sess = NetSession::new(&c.rt, &c.manifest, "mini_mlp", &c.codebook).unwrap();
    let assign = sess.assign_u32();
    let n = c.manifest.config.n;
    for (g, &code) in res.codes.iter().enumerate() {
        let row = &assign[g * n..(g + 1) * n];
        assert!(
            row.contains(&code),
            "group {g}: code {code} not among its candidates {row:?}"
        );
    }
}

#[test]
fn checkpoint_resume_is_byte_identical() {
    let Some(c) = campaign(0) else { return };
    let dir = std::env::temp_dir().join("vq4all_resume_test_ckpt");
    let _ = std::fs::remove_dir_all(&dir);

    // Run A: 4 steps, checkpoint, 4 more steps.
    let mut a = NetSession::new(&c.rt, &c.manifest, "mini_mlp", &c.codebook).unwrap();
    let mut pnc_a = PncScheduler::new(a.net.s_total, 0.9999);
    let mut stream = vq4all::coordinator::calib::CalibStream::new(
        a.calib_x.clone(),
        a.calib_y.clone(),
        &a.net.task,
        a.net.batch,
        99,
    );
    let mut batches = Vec::new();
    for _ in 0..8 {
        batches.push(stream.next_batch().unwrap());
    }
    for b in &batches[..4] {
        a.train_step(b).unwrap();
    }
    pnc_a.scan(a.z(), a.n);
    checkpoint::save(&dir, &a, &pnc_a, 4).unwrap();
    for b in &batches[4..] {
        a.train_step(b).unwrap();
    }

    // Run B: restore at step 4, replay the same last 4 batches.
    let mut b = NetSession::new(&c.rt, &c.manifest, "mini_mlp", &c.codebook).unwrap();
    let mut pnc_b = PncScheduler::new(b.net.s_total, 0.9999);
    let step = checkpoint::load(&dir, &mut b, &mut pnc_b).unwrap();
    assert_eq!(step, 4);
    for batch in &batches[4..] {
        b.train_step(batch).unwrap();
    }

    assert_eq!(a.z(), b.z(), "resumed z diverged from continuous run");
    assert_eq!(
        pnc_a.frozen_tensor(),
        pnc_b.frozen_tensor(),
        "freeze state diverged"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn infer_hard_serves_correct_shapes() {
    let Some(c) = campaign(6) else { return };
    let res = c.construct("mini_mlp").unwrap();
    let mut sess = NetSession::new(&c.rt, &c.manifest, "mini_mlp", &c.codebook).unwrap();
    let codes = sess.codes_tensor(&res.codes);
    let eb = sess.net.eval_batch;
    let rows: Vec<usize> = (0..eb).collect();
    let x = vq4all::coordinator::calib::gather_rows(&sess.test_x, &rows).unwrap();
    let out = sess.eval_infer(&codes, &[x]).unwrap();
    assert_eq!(out.len(), 1);
    assert_eq!(out[0].shape[0], eb, "batch dim preserved");
    assert!(out[0].as_f32().unwrap().iter().all(|v| v.is_finite()));
}

#[test]
fn rust_codebook_matches_python_export_distribution() {
    // §4.1 cross-check: the native KDE sampler must produce a codebook
    // whose first two moments match the python-exported one (they sample
    // the same KDE pool family).
    let Some(m) = manifest_or_skip() else { return };
    let nets: Vec<String> = m.networks.iter().map(|n| n.name.clone()).collect();
    let refs: Vec<&str> = nets.iter().map(|s| s.as_str()).collect();
    let native = Campaign::build_codebook_from(&m, &refs, 7).unwrap();
    let exported =
        vq4all::tensor::io::read_tensor(&m.path(&m.codebook_file)).unwrap();
    let stats = |t: &vq4all::tensor::Tensor| {
        let v = t.as_f32().unwrap();
        let mean = v.iter().map(|&x| x as f64).sum::<f64>() / v.len() as f64;
        let var = v.iter().map(|&x| (x as f64 - mean).powi(2)).sum::<f64>() / v.len() as f64;
        (mean, var.sqrt())
    };
    let (m1, s1) = stats(&native);
    let (m2, s2) = stats(&exported);
    assert!((m1 - m2).abs() < 0.05, "means diverged: {m1} vs {m2}");
    assert!(
        (s1 / s2 - 1.0).abs() < 0.35,
        "stds diverged: {s1} vs {s2}"
    );
}

#[test]
fn special_layer_pass_compresses_head_without_collapse() {
    // §5.1: the output head gets a private per-layer codebook; accuracy
    // must survive and the size accounting must shrink.
    let mut cfg = CampaignConfig {
        steps: 12,
        eval_interval: 0,
        ..CampaignConfig::default()
    };
    cfg.output_codebook = Some((64, 4));
    let with = match Campaign::load(&artifacts(), cfg.clone()) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("skipping (artifacts/PJRT unavailable): {e}");
            return;
        }
    };
    let res_special = with.construct("mini_mlp").unwrap();

    cfg.output_codebook = None;
    let without = Campaign::load(&artifacts(), cfg).unwrap();
    let res_plain = without.construct("mini_mlp").unwrap();

    assert!(
        res_special.sizes.other_bytes < res_plain.sizes.other_bytes,
        "special pass did not shrink the head: {} !< {}",
        res_special.sizes.other_bytes,
        res_plain.sizes.other_bytes
    );
    assert!(
        res_special.sizes.codebook_bytes > 0,
        "private codebook must be charged"
    );
    assert!(
        res_special.hard_metric > res_plain.hard_metric - 0.15,
        "head quantization collapsed accuracy: {} vs {}",
        res_special.hard_metric,
        res_plain.hard_metric
    );
    assert!(res_special.sizes.ratio() > res_plain.sizes.ratio());
}
