//! Integration: the serving stack (engine plane + server front-ends)
//! over the real `infer_hard` artifact for mini_mlp.

use std::sync::Arc;

use vq4all::coordinator::{Campaign, NetSession};
use vq4all::serving::batcher::BatcherConfig;
use vq4all::serving::server::Server;
use vq4all::serving::{Engine, EngineConfig, HostedNet};
use vq4all::util::config::CampaignConfig;
use vq4all::util::rng::Rng;
use vq4all::vq::{Codebook, StagedCodes};

/// Host constructed nets' packed streams on a decode plane (each stream
/// is segmented so its row space covers the request rows the tests use;
/// `device_batch` carries the artifact's fixed eval batch, which the
/// plane's batches must match).
fn plane_for(
    c: &Campaign,
    nets: &[(&vq4all::coordinator::NetResult, usize)],
    shards: usize,
    bc: BatcherConfig,
) -> Option<Engine> {
    let words = c.codebook.as_f32().ok()?.to_vec();
    let cb = Arc::new(Codebook::new(c.manifest.config.k, c.manifest.config.d, words));
    let hosted: Vec<HostedNet> = nets
        .iter()
        .map(|(res, eval_batch)| HostedNet {
            name: res.name.clone(),
            codes: StagedCodes::single(res.packed.clone()),
            codebook: cb.clone(),
            codes_per_row: (res.packed.count / 64).max(1),
            device_batch: *eval_batch,
        })
        .collect();
    Engine::new(
        EngineConfig {
            shards,
            cache_bytes: 1 << 20,
            max_queue_depth: 0,
            batcher: bc,
            obs: Default::default(),
        },
        hosted,
    )
    .ok()
}

/// Load the campaign, or `None` (with a visible skip note) when the
/// artifacts or the PJRT runtime are unavailable in this build — the
/// serving stack needs both.
fn campaign(steps: usize) -> Option<Campaign> {
    let cfg = CampaignConfig {
        steps,
        eval_interval: 0,
        ..CampaignConfig::default()
    };
    match Campaign::load(&vq4all::runtime::Manifest::default_dir(), cfg) {
        Ok(c) => Some(c),
        Err(e) => {
            eprintln!("skipping serving integration test (run `make artifacts` with a real xla build): {e}");
            None
        }
    }
}

#[test]
fn server_serves_every_request_exactly_once() {
    let Some(c) = campaign(6) else { return };
    let res = c.construct("mini_mlp").unwrap();
    let mut sess = NetSession::new(&c.rt, &c.manifest, "mini_mlp", &c.codebook).unwrap();
    let codes = sess.codes_tensor(&res.codes);
    let eval_batch = sess.net.eval_batch;

    let bc = BatcherConfig {
        max_batch: 16,
        max_linger_ns: 50_000,
    };
    let Some(plane) = plane_for(&c, &[(&res, eval_batch)], 1, bc) else { return };
    let mut server = Server::new(vec![(&mut sess, codes)], plane, None).unwrap();
    let mut rng = Rng::new(11);
    let total = 75usize;
    for i in 0..total {
        server.submit("mini_mlp", rng.below(64)).unwrap();
        if i % 7 == 0 {
            server.tick(60_000);
            while server.dispatch_one().unwrap() > 0 {}
        }
    }
    server.drain_all().unwrap();

    let st = &server.stats["mini_mlp"];
    assert_eq!(st.served as usize, total, "requests lost or duplicated");
    assert_eq!(st.latency_ns.count() as usize, total, "latency sample per request");
    assert!(st.batches > 0 && st.batches as usize <= total);
    // Latencies are nonnegative and finite.
    assert!(st.latency_ns.min() >= 0.0 && st.latency_ns.mean().is_finite());
    assert!(st.latency_ns.percentile(99.0) >= st.latency_ns.percentile(50.0));
    // The plane is the only router: its conservation ledger must close.
    let (acc, disp, shed) = server.plane.counters();
    assert_eq!(acc, disp + shed, "plane conservation violated");
    assert_eq!(shed, 0, "unbounded plane shed requests");
    assert_eq!(acc as usize, total);
    // The decode plane saw every dispatched weight row.
    let cs = server.plane.cache_stats();
    assert_eq!(
        cs.lookups,
        st.rows_from_cache + st.rows_decoded,
        "plane lookup accounting"
    );
    assert!(cs.lookups > 0, "plane never consulted");
}

#[test]
fn multi_net_server_interleaves_without_cross_talk() {
    let Some(c) = campaign(4) else { return };
    let nets = ["mini_mlp", "mini_resnet18"];
    let mut pairs = Vec::new();
    let mut results = Vec::new();
    for n in nets {
        let res = c.construct(n).unwrap();
        let sess = NetSession::new(&c.rt, &c.manifest, n, &c.codebook).unwrap();
        let codes = sess.codes_tensor(&res.codes);
        results.push((res, sess.net.eval_batch));
        pairs.push((sess, codes));
    }
    let bc = BatcherConfig {
        max_batch: 8,
        max_linger_ns: 10_000,
    };
    let hosted: Vec<(&vq4all::coordinator::NetResult, usize)> =
        results.iter().map(|(r, eb)| (r, *eb)).collect();
    // Two shards: each net routes on its own shard of the plane.
    let Some(plane) = plane_for(&c, &hosted, 2, bc) else { return };
    let refs: Vec<(&mut NetSession, vq4all::tensor::Tensor)> = pairs
        .iter_mut()
        .map(|(s, c2)| (s, c2.clone()))
        .collect();
    let mut server = Server::new(refs, plane, None).unwrap();
    let mut rng = Rng::new(3);
    let mut per_net = std::collections::BTreeMap::new();
    for _ in 0..60 {
        let n = nets[rng.below(2)];
        *per_net.entry(n.to_string()).or_insert(0u64) += 1;
        server.submit(n, rng.below(32)).unwrap();
    }
    server.drain_all().unwrap();
    for n in nets {
        assert_eq!(
            server.stats[n].served,
            per_net.get(n).copied().unwrap_or(0),
            "{n}: served count mismatch"
        );
    }
    let (acc, disp, shed) = server.plane.counters();
    assert_eq!((acc, disp, shed), (60, 60, 0), "plane conservation across shards");
}

#[test]
fn tcp_server_answers_over_loopback() {
    use std::net::{TcpListener, TcpStream};
    use vq4all::serving::tcp::{
        client_metrics, client_request, client_stats, client_trace, Shutdown, TcpServer,
    };

    let Some(c) = campaign(4) else { return };
    let res = c.construct("mini_mlp").unwrap();
    let sess = NetSession::new(&c.rt, &c.manifest, "mini_mlp", &c.codebook).unwrap();
    let codes = sess.codes_tensor(&res.codes);
    let eval_batch = sess.net.eval_batch;
    let bc = BatcherConfig {
        max_batch: 4,
        max_linger_ns: 1_000_000, // 1ms
    };
    let Some(plane) = plane_for(&c, &[(&res, eval_batch)], 1, bc) else { return };
    let mut server = TcpServer::new(vec![(sess, codes)], plane, None).unwrap();
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let shutdown = Shutdown::new();
    let sd = shutdown.clone();
    let addr2 = addr.clone();
    let client = std::thread::spawn(move || {
        let mut conn = TcpStream::connect(&addr2).unwrap();
        let mut oks = 0;
        for row in 0..10usize {
            let resp = client_request(&mut conn, "mini_mlp", row).unwrap();
            assert!(resp.req_bool("ok").unwrap(), "request {row} failed: {resp}");
            assert_eq!(resp.req_usize("row").unwrap(), row);
            let cls = resp.req_usize("argmax").unwrap();
            assert!(cls < 10, "argmax {cls} out of class range");
            oks += 1;
        }
        // Unknown network -> structured error, connection stays usable.
        let resp = client_request(&mut conn, "ghost", 0).unwrap();
        assert!(!resp.req_bool("ok").unwrap());
        // The /stats verb answers on the same connection with the
        // plane's admission + decode-throughput counters.
        let stats = client_stats(&mut conn).unwrap();
        assert!(stats.req_bool("ok").unwrap() && stats.req_bool("stats").unwrap());
        assert_eq!(stats.req_usize("accepted").unwrap(), 10);
        assert_eq!(stats.req_usize("dispatched").unwrap(), 10);
        assert_eq!(stats.req_usize("shed").unwrap(), 0);
        assert!(
            stats.req_usize("rows_decoded").unwrap() + stats.req_usize("rows_from_cache").unwrap()
                > 0,
            "decode-throughput counters must be live"
        );
        let per_net = stats.req("per_net").unwrap().get("mini_mlp").expect("hosted net entry");
        assert_eq!(per_net.req_usize("served").unwrap(), 10);
        // The /stats latency families carry the unified labeled shape:
        // wall-clock microseconds per net, engine-clock queue wait.
        let lat = per_net.req("latency").unwrap();
        assert_eq!(lat.req_str("unit").unwrap(), "us");
        assert_eq!(lat.req_str("clock").unwrap(), "wall");
        assert_eq!(lat.req_usize("count").unwrap(), 10);
        // The /metrics verb answers valid Prometheus text exposition on
        // the same connection (ISSUE-8 acceptance: parse it here), and
        // the JSON format mirrors the same snapshot.
        let m = client_metrics(&mut conn, false).unwrap();
        assert!(m.req_bool("ok").unwrap() && m.req_bool("metrics").unwrap());
        assert!(m.req_str("content_type").unwrap().starts_with("text/plain"));
        let body = m.req_str("body").unwrap();
        let samples = vq4all::serving::obs::expose::check_exposition(body)
            .expect("/metrics body must be valid Prometheus text");
        assert!(samples > 0, "exposition carried no samples");
        assert!(
            body.contains("vq4all_requests_dispatched_total 10"),
            "dispatched counter missing from exposition"
        );
        let mj = client_metrics(&mut conn, true).unwrap();
        let snap = mj.req("snapshot").expect("json snapshot");
        assert_eq!(snap.req_usize("accepted").unwrap(), 10);
        assert_eq!(snap.req_usize("dispatched").unwrap(), 10);
        assert_eq!(snap.req_usize("pending").unwrap(), 0);
        // The /trace verb reports the flight recorder; the only event
        // so far is the ghost-net hosting error recorded above.
        let tr = client_trace(&mut conn).unwrap();
        assert!(tr.req_bool("ok").unwrap() && tr.req_bool("trace").unwrap());
        let events = tr.req("events").unwrap().as_arr().expect("events array").to_vec();
        assert_eq!(events.len(), 1, "expected exactly the ghost-net event");
        assert_eq!(events[0].req_str("kind").unwrap(), "hosting_error");
        assert_eq!(events[0].req_str("net").unwrap(), "ghost");
        assert_eq!(tr.req_usize("dropped").unwrap(), 0);
        sd.trigger();
        let _ = TcpStream::connect(&addr2); // wake the acceptor
        oks
    });
    let served = server.serve(listener, shutdown, 0).unwrap();
    let oks = client.join().unwrap();
    assert_eq!(oks, 10);
    assert_eq!(served, 10);
    let st = &server.stats["mini_mlp"];
    assert_eq!(st.served, 10);
    assert_eq!(st.latency_us.count(), 10, "bounded latency sample per request");
    assert!(st.latency_us.min() >= 0.0);
    assert_eq!(server.stats["ghost"].errors, 1);
    // The plane routed every request: conservation closes on it too.
    let (acc, disp, shed) = server.plane.counters();
    assert_eq!((acc, disp, shed), (10, 10, 0), "plane conservation (wall clock)");
    assert!(server.plane.cache_stats().lookups > 0, "plane never consulted");
}
