//! Property tests over the coordinator's pure invariants (DESIGN.md §8):
//! PNC freeze monotonicity, router conservation, batcher conservation,
//! ratio/collapse identities, pack/unpack, and KDE sampling support.
//!
//! No artifacts needed — everything here is host-side logic.

use vq4all::coordinator::PncScheduler;
use vq4all::serving::batcher::{should_fire, Batch, BatcherConfig};
use vq4all::serving::Router;
use vq4all::testing::{proptest, Gen};
use vq4all::util::rng::Rng;
use vq4all::vq::pack::{pack_codes, unpack_codes};
use vq4all::vq::ratios::{effective_ratios, hard_codes, max_ratios, FreezeState};
use vq4all::vq::KdeSampler;
use vq4all::{prop_assert, prop_assert_eq};

fn gen_z(g: &mut Gen, s: usize, n: usize) -> Vec<f32> {
    g.vec_uniform((s * n)..=(s * n), -12.0, 12.0)
}

#[test]
fn pnc_freeze_is_monotone_and_sticky() {
    proptest(|g| {
        let s = g.usize_in(1, 40);
        let n = g.usize_in(2, 8);
        let alpha = g.f32_in(0.5, 0.99999) as f64;
        let mut pnc = PncScheduler::new(s, alpha);
        let mut prev: Vec<f32> = vec![0.0; s];
        let mut prev_idx: Vec<i32> = vec![0; s];
        for _ in 0..6 {
            let z = gen_z(g, s, n);
            pnc.scan(&z, n);
            let now = pnc.frozen_tensor();
            let idx = pnc.frozen_idx_tensor();
            for gi in 0..s {
                prop_assert!(
                    now[gi] >= prev[gi],
                    "group {gi} unfroze: {} -> {}",
                    prev[gi],
                    now[gi]
                );
                if prev[gi] > 0.5 {
                    prop_assert_eq!(idx[gi], prev_idx[gi]);
                }
            }
            prev = now;
            prev_idx = idx;
        }
        // History is monotone nondecreasing.
        for w in pnc.history.windows(2) {
            prop_assert!(w[0] <= w[1], "history decreased: {:?}", pnc.history);
        }
        Ok(())
    });
}

#[test]
fn pnc_scan_freezes_exactly_the_groups_past_alpha() {
    proptest(|g| {
        let s = g.usize_in(1, 30);
        let n = g.usize_in(2, 6);
        let alpha = 0.99;
        let z = gen_z(g, s, n);
        let mut pnc = PncScheduler::new(s, alpha);
        pnc.scan(&z, n);
        for (gi, (r, m)) in max_ratios(&z, n).into_iter().enumerate() {
            let frozen = pnc.state.is_frozen(gi);
            prop_assert_eq!(frozen, (r as f64) > alpha);
            if frozen {
                prop_assert_eq!(pnc.state.frozen_idx[gi] as usize, m);
            }
        }
        Ok(())
    });
}

#[test]
fn hard_codes_equal_argmax_when_unfrozen_and_frozen_slot_otherwise() {
    proptest(|g| {
        let s = g.usize_in(1, 30);
        let n = g.usize_in(2, 6);
        let k = 64u32;
        let z = gen_z(g, s, n);
        let assign = g.vec_u32((s * n)..=(s * n), k);
        let mut fs = FreezeState::new(s);
        for gi in 0..s {
            if g.bool() {
                fs.freeze(gi, g.usize_in(0, n - 1));
            }
        }
        let codes = hard_codes(&z, &assign, n, &fs);
        for gi in 0..s {
            let row_z = &z[gi * n..(gi + 1) * n];
            let slot = if fs.is_frozen(gi) {
                fs.frozen_idx[gi] as usize
            } else {
                row_z
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .unwrap()
                    .0
            };
            prop_assert_eq!(codes[gi], assign[gi * n + slot]);
        }
        Ok(())
    });
}

#[test]
fn softmax_rows_sum_to_one_and_are_positive() {
    proptest(|g| {
        let s = g.usize_in(1, 50);
        let n = g.usize_in(1, 8);
        let z = gen_z(g, s, n);
        // No frozen groups -> effective_ratios is a plain row softmax.
        let r = effective_ratios(&z, n, &FreezeState::new(s));
        for gi in 0..s {
            let row = &r[gi * n..(gi + 1) * n];
            let sum: f32 = row.iter().sum();
            prop_assert!((sum - 1.0).abs() < 1e-4, "row {gi} sums to {sum}");
            prop_assert!(row.iter().all(|&x| x >= 0.0), "negative ratio");
        }
        Ok(())
    });
}

#[test]
fn router_conserves_every_request_exactly_once() {
    proptest(|g| {
        let nnets = g.usize_in(1, 5);
        let names: Vec<String> = (0..nnets).map(|i| format!("net{i}")).collect();
        let refs: Vec<&str> = names.iter().map(|s| s.as_str()).collect();
        let mut r = Router::new(&refs);
        let total = g.usize_in(0, 200);
        let mut ids = Vec::new();
        for t in 0..total {
            let net = &names[g.usize_in(0, nnets - 1)];
            ids.push(r.submit(net, g.usize_in(0, 63), t as u64).unwrap());
        }
        // ids are unique
        let mut sorted = ids.clone();
        sorted.sort_unstable();
        sorted.dedup();
        prop_assert_eq!(sorted.len(), ids.len());

        let mut served = Vec::new();
        while let Some(i) = r.pick() {
            for req in r.drain(i, g.usize_in(1, 16)) {
                served.push(req.id);
            }
        }
        served.sort_unstable();
        prop_assert_eq!(served, sorted);
        let (acc, disp) = r.counters();
        prop_assert_eq!(acc, disp);
        prop_assert_eq!(r.total_pending(), 0usize);
        Ok(())
    });
}

#[test]
fn router_pick_never_starves_a_nonempty_queue() {
    proptest(|g| {
        let names = ["a", "b", "c"];
        let mut r = Router::new(&names);
        // Heavy load on one queue, trickle on the others.
        for t in 0..60 {
            r.submit("a", t, t as u64).unwrap();
        }
        r.submit("b", 0, 0).unwrap();
        r.submit("c", 0, 0).unwrap();
        let mut served_nets = std::collections::BTreeSet::new();
        // Drain with small batches; every queue must be picked eventually.
        for _ in 0..100 {
            match r.pick() {
                Some(i) => {
                    served_nets.insert(r.net_name(i).to_string());
                    r.drain(i, g.usize_in(1, 4));
                }
                None => break,
            }
        }
        prop_assert_eq!(served_nets.len(), 3usize);
        Ok(())
    });
}

#[test]
fn batch_form_preserves_requests_and_pads_with_real_rows() {
    proptest(|g| {
        let device_batch = g.usize_in(1, 32);
        let nreq = g.usize_in(1, device_batch);
        let reqs: Vec<vq4all::serving::Request> = (0..nreq)
            .map(|i| vq4all::serving::Request {
                id: i as u64,
                net: "x".into(),
                row: g.usize_in(0, 99),
                arrived_ns: i as u64,
                deadline_ns: 0,
            })
            .collect();
        let rows: Vec<usize> = reqs.iter().map(|r| r.row).collect();
        let b = Batch::form("x", reqs, device_batch);
        prop_assert_eq!(b.rows.len(), device_batch);
        prop_assert_eq!(b.padded, device_batch - nreq);
        prop_assert_eq!(&b.rows[..nreq], &rows[..]);
        // Padding repeats real rows only.
        for &row in &b.rows[nreq..] {
            prop_assert!(rows.contains(&row), "padding invented row {row}");
        }
        let u = b.utilization();
        prop_assert!(u > 0.0 && u <= 1.0, "utilization {u} out of range");
        Ok(())
    });
}

#[test]
fn should_fire_is_monotone_in_depth_and_age() {
    proptest(|g| {
        let cfg = BatcherConfig {
            max_batch: g.usize_in(1, 64),
            max_linger_ns: g.usize_in(0, 1_000_000) as u64,
        };
        let depth = g.usize_in(1, 128);
        let arrival = g.usize_in(0, 1_000_000) as u64;
        let now = arrival + g.usize_in(0, 2_000_000) as u64;
        let fired = should_fire(&cfg, depth, arrival, now);
        // More depth never un-fires.
        if fired {
            prop_assert!(should_fire(&cfg, depth + 1, arrival, now), "deeper un-fired");
            prop_assert!(should_fire(&cfg, depth, arrival, now + 1), "older un-fired");
        }
        // Full batch always fires; empty never does.
        prop_assert!(should_fire(&cfg, cfg.max_batch, now, now), "full batch must fire");
        prop_assert!(!should_fire(&cfg, 0, 0, u64::MAX), "empty fired");
        Ok(())
    });
}

#[test]
fn pack_unpack_identity_all_bitwidths() {
    proptest(|g| {
        let bits = g.usize_in(1, 24) as u32;
        let max = if bits >= 24 { 1 << 24 } else { 1u32 << bits };
        let codes = g.vec_u32(0..=300, max);
        let p = pack_codes(&codes, bits);
        prop_assert_eq!(unpack_codes(&p), codes);
        // Tightness: byte count is ceil(len*bits/8).
        prop_assert_eq!(p.bytes(), (codes.len() * bits as usize).div_ceil(8));
        Ok(())
    });
}

#[test]
fn kde_samples_stay_within_plausible_support() {
    proptest(|g| {
        let d = [1usize, 2, 4][g.usize_in(0, 2)];
        let npts = g.usize_in(8, 200) / d * d;
        let pool = g.vec_uniform(npts..=npts, -1.0, 1.0);
        let h = 0.01f32;
        let kde = KdeSampler::new(pool.clone(), d, h);
        let mut rng = Rng::new(g.rng.next_u64());
        let cb = kde.sample_codebook(32, &mut rng);
        // Every codeword = some pool point + N(0, h): must lie within
        // pool range +- 6h.
        let (lo, hi) = (-1.0 - 6.0 * h, 1.0 + 6.0 * h);
        for (i, w) in cb.words.iter().enumerate() {
            prop_assert!(
                (lo..=hi).contains(w),
                "codeword elem {i} = {w} outside KDE support"
            );
        }
        prop_assert_eq!(cb.words.len(), 32 * d);
        Ok(())
    });
}

#[test]
fn freeze_state_progress_counts_match() {
    proptest(|g| {
        let s = g.usize_in(1, 64);
        let mut fs = FreezeState::new(s);
        let mut expected = 0usize;
        for gi in 0..s {
            if g.bool() {
                fs.freeze(gi, 0);
                expected += 1;
            }
        }
        prop_assert_eq!(fs.num_frozen(), expected);
        prop_assert_eq!(fs.all_frozen(), expected == s);
        Ok(())
    });
}
