//! Property tests over the substrate layers: quantization baselines
//! (uniform / ternary / PQF-style permutation), host tensor ops, k-means,
//! and the ROM/area model — the pieces every experiment harness rests on.

use vq4all::quant::pvq::{
    apply_col_permutation, random_permutation, undo_col_permutation,
    variance_balancing_permutation,
};
use vq4all::quant::ternary::{dequantize as tern_dequant, ternarize, ternary_mse};
use vq4all::quant::uniform::{self, Granularity};
use std::sync::Arc;

use vq4all::rom::AreaModel;
use vq4all::serving::engine::router::Request;
use vq4all::serving::engine::{decode_into, Admission, Engine, EngineConfig, HostedNet, RowWindow};
use vq4all::serving::{decode_batch, Batch, BatcherConfig};
use vq4all::tensor::ops;
use vq4all::testing::{proptest, Gen};
use vq4all::util::rng::Rng;
use vq4all::util::threadpool::ThreadPool;
use vq4all::vq::assign::{candidates, candidates_with, AssignInit};
use vq4all::vq::kmeans::{kmeans, KmeansOpts};
use vq4all::vq::pack::{
    pack_codes, pack_codes_reference, unpack_codes, unpack_codes_with, unpack_one, unpack_range,
    unpack_range_reference, StagedCodes,
};
use vq4all::vq::simd;
use vq4all::vq::Codebook;
use vq4all::{prop_assert, prop_assert_eq};

fn weights(g: &mut Gen, len: usize) -> Vec<f32> {
    let mut w = g.vec_normal(len..=len);
    for v in w.iter_mut() {
        *v *= 0.05; // realistic weight scale
    }
    w
}

#[test]
fn uniform_quant_error_bounded_by_half_step() {
    proptest(|g| {
        let bits = g.usize_in(2, 8) as u32;
        let len = g.usize_in(1, 400);
        let w = weights(g, len);
        let q = uniform::quantize(&w, bits, Granularity::PerTensor);
        let mut back = vec![0.0; w.len()];
        uniform::dequantize(&q, Granularity::PerTensor, &mut back);
        let step = q.scales[0];
        for (i, (&a, &b)) in w.iter().zip(&back).enumerate() {
            prop_assert!(
                (a - b).abs() <= step * 0.5 + 1e-6,
                "elem {i}: |{a} - {b}| > step/2 = {}",
                step * 0.5
            );
        }
        Ok(())
    });
}

#[test]
fn uniform_quant_mse_decreases_with_bits() {
    proptest(|g| {
        let len = g.usize_in(64, 400);
        let w = weights(g, len);
        let mut prev = f64::INFINITY;
        for bits in [1u32, 2, 3, 4, 6, 8] {
            let mse = uniform::quant_mse(&w, bits, Granularity::PerTensor);
            prop_assert!(
                mse <= prev + 1e-12,
                "mse rose from {prev} to {mse} at {bits} bits"
            );
            prev = mse;
        }
        Ok(())
    });
}

#[test]
fn per_row_uniform_never_worse_than_per_tensor() {
    proptest(|g| {
        let rows = g.usize_in(2, 8);
        let cols = g.usize_in(4, 32);
        // Rows at very different scales — the per-channel motivation.
        let mut w = Vec::new();
        for r in 0..rows {
            let scale = 0.01 * (r + 1) as f32 * (r + 1) as f32;
            for v in g.vec_normal(cols..=cols) {
                w.push(v * scale);
            }
        }
        let bits = g.usize_in(2, 6) as u32;
        let pt = uniform::quant_mse(&w, bits, Granularity::PerTensor);
        let pr = uniform::quant_mse(&w, bits, Granularity::PerRow { rows });
        prop_assert!(pr <= pt * 1.0001, "per-row {pr} worse than per-tensor {pt}");
        Ok(())
    });
}

#[test]
fn ternary_roundtrip_uses_three_levels_and_optimal_scale_beats_naive() {
    proptest(|g| {
        let len = g.usize_in(8, 300);
        let w = weights(g, len);
        let t = ternarize(&w, 0.7);
        let mut back = vec![0.0; w.len()];
        tern_dequant(&t, &mut back);
        let uniq: std::collections::BTreeSet<i64> = back
            .iter()
            .map(|&x| (x * 1e4).round() as i64)
            .collect();
        prop_assert!(uniq.len() <= 3, "more than 3 levels: {uniq:?}");
        let mse = ternary_mse(&w, 0.7);
        let zero_mse: f64 =
            w.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>() / w.len() as f64;
        prop_assert!(mse <= zero_mse + 1e-12, "ternary worse than all-zeros");
        Ok(())
    });
}

#[test]
fn pqf_permutation_roundtrips_and_reduces_bucket_variance_spread() {
    proptest(|g| {
        let rows = g.usize_in(2, 10);
        let d = [2usize, 4][g.usize_in(0, 1)];
        let cols = d * g.usize_in(2, 8);
        let w = weights(g, rows * cols);

        // Round-trip identity for any permutation.
        let perm = random_permutation(cols, &mut g.rng);
        let p = apply_col_permutation(&w, rows, cols, &perm);
        let back = undo_col_permutation(&p, rows, cols, &perm);
        prop_assert_eq!(back, w.clone());

        // The variance-balancing permutation is a valid permutation.
        let vb = variance_balancing_permutation(&w, rows, cols, d);
        let mut sorted = vb.clone();
        sorted.sort_unstable();
        prop_assert_eq!(sorted, (0..cols).collect::<Vec<_>>());
        Ok(())
    });
}

#[test]
fn kmeans_mse_never_increases_with_k_and_beats_random_codebook() {
    proptest(|g| {
        let d = 2usize;
        let n = g.usize_in(40, 200);
        let w = weights(g, n * d);
        let opts = KmeansOpts::default();
        let m2 = kmeans(&w, d, 2, &opts).mse;
        let m8 = kmeans(&w, d, 8, &opts).mse;
        let m32 = kmeans(&w, d, 32.min(n), &opts).mse;
        prop_assert!(m8 <= m2 * 1.05, "k=8 ({m8}) worse than k=2 ({m2})");
        prop_assert!(m32 <= m8 * 1.05, "k=32 ({m32}) worse than k=8 ({m8})");
        Ok(())
    });
}

/// The tentpole's determinism contract: the pooled hot paths must be
/// **bit-identical** to the serial (`threads = 1`) path across random
/// shapes, thread counts, and all three `AssignInit` modes — per-chunk
/// RNG streams derive from chunk indices, and every float reduction sums
/// per-chunk partials in chunk order.
#[test]
fn parallel_candidates_and_kmeans_are_bit_identical_to_serial() {
    proptest(|g| {
        // d = 8 draws the pruned-scan dispatch into the contract too.
        let d = [1usize, 2, 4, 8][g.usize_in(0, 3)];
        let s = g.usize_in(1, 400);
        let k = g.usize_in(2, 24);
        let n = g.usize_in(1, k);
        let threads = g.usize_in(2, 8);
        let words = g.vec_normal((k * d)..=(k * d));
        let cb = Codebook::new(k, d, words);
        let flat = g.vec_normal((s * d)..=(s * d));
        let pool = ThreadPool::new(threads);
        let seed = g.rng.next_u64();

        for init in [AssignInit::Random, AssignInit::Cosine, AssignInit::Euclid] {
            let mut r_serial = Rng::new(seed);
            let mut r_par = Rng::new(seed);
            let a = candidates(&flat, &cb, n, init, &mut r_serial);
            let b = candidates_with(&flat, &cb, n, init, &mut r_par, Some(&pool));
            prop_assert_eq!(a.assign, b.assign);
            let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
            prop_assert_eq!(bits(&a.dist), bits(&b.dist));
            // Both paths must advance the caller RNG identically.
            prop_assert_eq!(r_serial.next_u64(), r_par.next_u64());
        }

        let serial = kmeans(
            &flat,
            d,
            k,
            &KmeansOpts {
                threads: 1,
                seed,
                ..Default::default()
            },
        );
        let par = kmeans(
            &flat,
            d,
            k,
            &KmeansOpts {
                threads,
                seed,
                ..Default::default()
            },
        );
        prop_assert_eq!(serial.codes, par.codes);
        let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        prop_assert_eq!(bits(&serial.codebook.words), bits(&par.codebook.words));
        prop_assert_eq!(serial.mse.to_bits(), par.mse.to_bits());
        prop_assert_eq!(serial.iterations, par.iterations);
        Ok(())
    });
}

/// Pack/unpack round-trips at every width 1..=32 with a bias toward the
/// awkward non-byte-aligned ones (3/5/7/13), `unpack_one` and
/// `unpack_range` agree with the bulk unpack, and the pooled bulk unpack
/// is bit-identical to serial (lengths are drawn past the chunk size so
/// the pooled path genuinely splits).
#[test]
fn pack_unpack_roundtrip_and_parallel_unpack_identical() {
    let pool = ThreadPool::new(4);
    proptest(|g| {
        let bits = if g.bool() {
            [3u32, 5, 7, 13][g.usize_in(0, 3)] // the awkward widths
        } else {
            g.usize_in(1, 32) as u32
        };
        let mask = if bits == 32 { u32::MAX } else { (1u32 << bits) - 1 };
        let len = g.usize_in(0, 3000);
        let codes: Vec<u32> = (0..len).map(|_| (g.rng.next_u64() as u32) & mask).collect();
        let p = pack_codes(&codes, bits);
        prop_assert_eq!(p.count, codes.len());
        prop_assert_eq!(p.bytes(), (len * bits as usize).div_ceil(8));

        let serial = unpack_codes(&p);
        prop_assert_eq!(serial.clone(), codes.clone());
        let parallel = unpack_codes_with(&p, Some(&pool));
        prop_assert_eq!(parallel, serial);

        if !codes.is_empty() {
            for _ in 0..8 {
                let i = g.usize_in(0, codes.len() - 1);
                prop_assert_eq!(unpack_one(&p, i), codes[i]);
            }
            let start = g.usize_in(0, codes.len() - 1);
            let end = g.usize_in(start, codes.len());
            let mut window = vec![0u32; end - start];
            unpack_range(&p, start, end, &mut window);
            prop_assert_eq!(window, codes[start..end].to_vec());
        }
        Ok(())
    });
}

/// Tentpole (word-level unpack): the specialized [`unpack_range`]
/// dispatch — byte-aligned lanes, sub-byte power-of-two loads, and the
/// general u64-window kernel — must be bit-identical to the retained
/// scalar reference at widths 1..=32 (biased to the awkward 3/5/7/13),
/// over stream lengths that include the pooled chunk boundary (1024
/// codes) and end-of-stream tails where the 8-byte window load must
/// zero-pad, on arbitrary sub-windows.  `unpack_one`'s direct word load
/// rides the same draws.
#[test]
fn wordwise_unpack_bit_identical_to_scalar_reference() {
    proptest(|g| {
        let bits = if g.bool() {
            [3u32, 5, 7, 13][g.usize_in(0, 3)]
        } else {
            g.usize_in(1, 32) as u32
        };
        let mask = if bits == 32 { u32::MAX } else { (1u32 << bits) - 1 };
        let len = match g.usize_in(0, 3) {
            0 => g.usize_in(0, 16),       // tiny, incl. empty: all-tail streams
            1 => g.usize_in(1020, 1030),  // the UNPACK_CHUNK boundary
            2 => 2048 + g.usize_in(0, 7), // exact multiples + small tails
            _ => g.usize_in(0, 3000),
        };
        let codes: Vec<u32> = (0..len).map(|_| (g.rng.next_u64() as u32) & mask).collect();
        let p = pack_codes(&codes, bits);

        let mut windows = vec![(0usize, len)];
        if len > 0 {
            let a = g.usize_in(0, len - 1);
            windows.push((a, g.usize_in(a, len)));
            // The end-of-stream tail: the last few codes force the
            // zero-padded window load.
            windows.push((len - g.usize_in(1, 9).min(len), len));
        }
        for (start, end) in windows {
            let mut fast = vec![0u32; end - start];
            let mut slow = vec![0u32; end - start];
            unpack_range(&p, start, end, &mut fast);
            unpack_range_reference(&p, start, end, &mut slow);
            prop_assert!(fast == slow, "bits={bits} len={len} [{start}, {end}) diverged");
            prop_assert_eq!(fast, codes[start..end].to_vec());
        }
        if len > 0 {
            for _ in 0..4 {
                let i = g.usize_in(0, len - 1);
                prop_assert_eq!(unpack_one(&p, i), codes[i]);
            }
            prop_assert_eq!(unpack_one(&p, len - 1), codes[len - 1]);
        }
        Ok(())
    });
}

/// Satellite (word-level pack): the u64-accumulator `pack_codes` must be
/// byte-identical to the retained bit-loop `pack_codes_reference` at
/// widths 1..=32 (biased to the awkward 3/5/7/13), over lengths that
/// include the u64-flush boundary and sub-word tails — and a
/// single-stage [`StagedCodes`] must be byte-identical to the legacy
/// packed stream (the `stages == 1` format guarantee the staged decode
/// plane rests on).
#[test]
fn wordwise_pack_byte_identical_and_single_stage_is_legacy_format() {
    proptest(|g| {
        let bits = if g.bool() {
            [3u32, 5, 7, 13][g.usize_in(0, 3)]
        } else {
            g.usize_in(1, 32) as u32
        };
        let mask = if bits == 32 { u32::MAX } else { (1u32 << bits) - 1 };
        let len = match g.usize_in(0, 2) {
            0 => g.usize_in(0, 16),  // tiny, incl. empty: tail-only streams
            1 => g.usize_in(60, 70), // around the u64 accumulator flushes
            _ => g.usize_in(0, 2000),
        };
        let codes: Vec<u32> = (0..len).map(|_| (g.rng.next_u64() as u32) & mask).collect();
        let fast = pack_codes(&codes, bits);
        let slow = pack_codes_reference(&codes, bits);
        prop_assert!(
            fast == slow,
            "bits={bits} len={len}: wordwise pack diverged from the bit-loop reference"
        );
        let staged = StagedCodes::single(fast);
        prop_assert_eq!(staged.stages(), 1);
        prop_assert!(
            *staged.stage(0) == slow,
            "single-stage staged stream is not byte-identical to the legacy pack"
        );
        prop_assert_eq!(staged.total_bits(), bits);
        prop_assert_eq!(staged.count(), len);
        Ok(())
    });
}

/// Satellite (staged residual encode): `encode_staged` — the PR-5 pruned
/// scan run per stage over a codebook prefix — must agree with the naive
/// `encode_staged_reference` on (per-stage packed bytes, f64 MSE bits,
/// per-stage residual MSE bits, utilization histograms), serial AND
/// pooled, for stage counts 1..=3 at widths 1..=32, on both sides of the
/// pruning dispatch threshold.
#[test]
fn staged_encode_bit_identical_to_reference_serial_and_pooled() {
    let pool = ThreadPool::new(4);
    proptest(|g| {
        let d = [1usize, 2, 4, 8, 16][g.usize_in(0, 4)];
        let k = g.usize_in(2, 32);
        let cb = Codebook::new(k, d, g.vec_normal((k * d)..=(k * d)));
        let s = g.usize_in(0, 200);
        let flat = g.vec_normal((s * d)..=(s * d));
        let nstages = g.usize_in(1, 3);
        let stage_bits: Vec<u32> = (0..nstages)
            .map(|_| {
                if g.bool() {
                    [3u32, 5, 7, 13][g.usize_in(0, 3)]
                } else {
                    g.usize_in(1, 32) as u32
                }
            })
            .collect();
        let r = cb.encode_staged_reference(&flat, &stage_bits);
        let a = cb.encode_staged(&flat, &stage_bits, None);
        let b = cb.encode_staged(&flat, &stage_bits, Some(&pool));
        for (enc, tag) in [(&a, "serial"), (&b, "pooled")] {
            prop_assert!(
                enc.codes == r.codes,
                "{tag}: staged streams diverged from reference (d={d}, bits={stage_bits:?})"
            );
            prop_assert_eq!(enc.mse.to_bits(), r.mse.to_bits());
            prop_assert_eq!(enc.stage_mse.len(), r.stage_mse.len());
            for (x, y) in enc.stage_mse.iter().zip(&r.stage_mse) {
                prop_assert_eq!(x.to_bits(), y.to_bits());
            }
            prop_assert!(enc.utilization == r.utilization, "{tag}: utilization diverged");
        }
        Ok(())
    });
}

/// Satellite (staged residual decode): the fused
/// `decode_staged_packed_into` (stage-0 gather write, later stages
/// wordwise unpack + gather-accumulate) must equal the scalar
/// `decode_staged_packed_into_reference` bit for bit across the gather
/// specializations (d = 1..=4) and the generic path, stage counts 1..=3,
/// widths 1..=32, on arbitrary sub-windows.
#[test]
fn staged_decode_bit_identical_to_reference_across_stage_counts() {
    proptest(|g| {
        let d = [1usize, 2, 3, 4, 7][g.usize_in(0, 4)];
        let k = g.usize_in(2, 32);
        let idx_bits = (usize::BITS - (k - 1).leading_zeros()).max(1);
        let cb = Codebook::new(k, d, g.vec_normal((k * d)..=(k * d)));
        let len = g.usize_in(0, 600);
        let nstages = g.usize_in(1, 3);
        let streams: Vec<_> = (0..nstages)
            .map(|_| {
                let biased = if g.bool() {
                    [3u32, 5, 7, 13][g.usize_in(0, 3)]
                } else {
                    g.usize_in(1, 32) as u32
                };
                let bits = biased.max(idx_bits);
                let codes: Vec<u32> = (0..len).map(|_| g.u32_below(k as u32)).collect();
                pack_codes(&codes, bits)
            })
            .collect();
        let staged = StagedCodes::new(streams);
        let (start, end) = if len == 0 {
            (0, 0)
        } else {
            let a = g.usize_in(0, len - 1);
            (a, g.usize_in(a, len))
        };
        let mut fast = vec![0.0f32; (end - start) * d];
        let mut slow = vec![0.0f32; (end - start) * d];
        cb.decode_staged_packed_into(&staged, start, end, &mut fast);
        cb.decode_staged_packed_into_reference(&staged, start, end, &mut slow);
        let fb = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        prop_assert!(
            fb(&fast) == fb(&slow),
            "d={d} stages={nstages} [{start}, {end}) staged decode diverged"
        );
        Ok(())
    });
}

/// Tentpole (fused decode): the wordwise + small-d-gather streaming
/// decode must equal the retained reference kernel bit for bit across
/// the gather specializations (d = 1..=4) and the generic path.
#[test]
fn fused_wordwise_decode_bit_identical_to_reference() {
    proptest(|g| {
        let d = [1usize, 2, 3, 4, 7][g.usize_in(0, 4)];
        let k = g.usize_in(2, 32);
        let idx_bits = (usize::BITS - (k - 1).leading_zeros()).max(1);
        let biased = if g.bool() {
            [3u32, 5, 7, 13][g.usize_in(0, 3)]
        } else {
            g.usize_in(1, 32) as u32
        };
        let bits = biased.max(idx_bits);
        let cb = Codebook::new(k, d, g.vec_normal((k * d)..=(k * d)));
        let len = g.usize_in(0, 600);
        let codes: Vec<u32> = (0..len).map(|_| g.u32_below(k as u32)).collect();
        let p = pack_codes(&codes, bits);
        let (start, end) = if len == 0 {
            (0, 0)
        } else {
            let a = g.usize_in(0, len - 1);
            (a, g.usize_in(a, len))
        };
        let mut fast = vec![0.0f32; (end - start) * d];
        let mut slow = vec![0.0f32; (end - start) * d];
        cb.decode_packed_into(&p, start, end, &mut fast);
        cb.decode_packed_into_reference(&p, start, end, &mut slow);
        let fb = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        prop_assert!(
            fb(&fast) == fb(&slow),
            "d={d} bits={bits} [{start}, {end}) fused decode diverged"
        );
        Ok(())
    });
}

/// Tentpole (pruned encode): the norm-seeded partial-distance scan must
/// agree with the retained brute-force reference on (codes, f64 MSE
/// bits, argmin tie-breaks) — on adversarial near-tie codebooks
/// (duplicated codewords, data points planted exactly on codewords so
/// zero-distance ties occur), serial and pooled, across the dispatch
/// threshold (d below and at/above PRUNE_MIN_D).
#[test]
fn pruned_encode_bit_identical_to_brute_reference() {
    let pool = ThreadPool::new(4);
    proptest(|g| {
        let d = [1usize, 2, 4, 8, 12, 16, 19][g.usize_in(0, 6)];
        let k = g.usize_in(2, 40);
        let mut words = g.vec_normal((k * d)..=(k * d));
        if g.bool() {
            // Exact duplicate codewords: ties must break first-index.
            for _ in 0..g.usize_in(1, 4) {
                let src = g.usize_in(0, k - 1);
                let dst = g.usize_in(0, k - 1);
                let row: Vec<f32> = words[src * d..(src + 1) * d].to_vec();
                words[dst * d..(dst + 1) * d].copy_from_slice(&row);
            }
        }
        let cb = Codebook::new(k, d, words);
        let s = g.usize_in(0, 300);
        let mut flat = g.vec_normal((s * d)..=(s * d));
        if s > 0 {
            // Plant exact codewords: distance 0, duplicated -> exact tie.
            for _ in 0..g.usize_in(0, 8) {
                let gi = g.usize_in(0, s - 1);
                let c = g.usize_in(0, k - 1);
                let w: Vec<f32> = cb.word(c).to_vec();
                flat[gi * d..(gi + 1) * d].copy_from_slice(&w);
            }
        }
        let (m_ref, c_ref) = cb.encode_nearest_reference(&flat);
        let (m_ser, c_ser) = cb.encode_nearest_with(&flat, None);
        prop_assert!(m_ref.to_bits() == m_ser.to_bits(), "serial MSE diverged (d={d})");
        prop_assert_eq!(c_ref.clone(), c_ser);
        let (m_par, c_par) = cb.encode_nearest_with(&flat, Some(&pool));
        prop_assert!(m_ref.to_bits() == m_par.to_bits(), "pooled MSE diverged (d={d})");
        prop_assert_eq!(c_ref, c_par);
        Ok(())
    });
}

/// Tentpole (pruned top-n assign): the Euclid candidate sweep must equal
/// the naive scratch-table + `argmin_n` reference — index tie-breaks
/// included — on both sides of the dispatch threshold, with the pooled
/// sweep identical to serial.
#[test]
fn pruned_assign_topn_matches_scratch_argmin_reference() {
    let pool = ThreadPool::new(4);
    proptest(|g| {
        let d = [2usize, 8, 12, 16][g.usize_in(0, 3)];
        let k = g.usize_in(2, 24);
        let mut words = g.vec_normal((k * d)..=(k * d));
        if g.bool() {
            let src = g.usize_in(0, k - 1);
            let dst = g.usize_in(0, k - 1);
            let row: Vec<f32> = words[src * d..(src + 1) * d].to_vec();
            words[dst * d..(dst + 1) * d].copy_from_slice(&row);
        }
        let cb = Codebook::new(k, d, words);
        let s = g.usize_in(1, 150);
        let mut flat = g.vec_normal((s * d)..=(s * d));
        for _ in 0..g.usize_in(0, 4) {
            let gi = g.usize_in(0, s - 1);
            let c = g.usize_in(0, k - 1);
            let w: Vec<f32> = cb.word(c).to_vec();
            flat[gi * d..(gi + 1) * d].copy_from_slice(&w);
        }
        let n = g.usize_in(1, k);
        let seed = g.rng.next_u64();
        let mut r = Rng::new(seed);
        let got = candidates(&flat, &cb, n, AssignInit::Euclid, &mut r);
        for gi in 0..s {
            let sub = &flat[gi * d..(gi + 1) * d];
            let scratch: Vec<f32> = (0..k).map(|c| ops::sq_dist(sub, cb.word(c))).collect();
            for (m, &c) in ops::argmin_n(&scratch, n).iter().enumerate() {
                prop_assert!(got.assign[gi * n + m] == c as u32, "g={gi} m={m} index diverged");
                prop_assert!(
                    got.dist[gi * n + m].to_bits() == scratch[c].to_bits(),
                    "g={gi} m={m} dist bits diverged"
                );
            }
        }
        let mut r2 = Rng::new(seed);
        let pooled = candidates_with(&flat, &cb, n, AssignInit::Euclid, &mut r2, Some(&pool));
        prop_assert_eq!(got.assign, pooled.assign);
        let fb = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        prop_assert_eq!(fb(&got.dist), fb(&pooled.dist));
        Ok(())
    });
}

/// Tentpole (pruned nearest scan, the k-means kernel): `nearest_pruned`
/// must equal the naive first-min scan bit for bit — argmin index,
/// distance bits, first-index tie-breaks — for arbitrary shapes and
/// planted exact ties.
#[test]
fn nearest_pruned_bit_identical_to_naive_first_min_scan() {
    proptest(|g| {
        let d = g.usize_in(1, 24);
        let k = g.usize_in(1, 40);
        let mut words = g.vec_normal((k * d)..=(k * d));
        if g.bool() && k >= 2 {
            let src = g.usize_in(0, k - 1);
            let dst = g.usize_in(0, k - 1);
            let row: Vec<f32> = words[src * d..(src + 1) * d].to_vec();
            words[dst * d..(dst + 1) * d].copy_from_slice(&row);
        }
        let sub: Vec<f32> = if g.bool() {
            let c = g.usize_in(0, k - 1);
            words[c * d..(c + 1) * d].to_vec()
        } else {
            g.vec_normal(d..=d)
        };
        let norms: Vec<f32> = words.chunks_exact(d).map(|w| ops::dot(w, w)).collect();
        let (gi, gd) = ops::nearest_pruned(&sub, &words, &norms);
        let mut best = 0usize;
        let mut best_d = f32::INFINITY;
        for c in 0..k {
            let dist = ops::sq_dist(&sub, &words[c * d..(c + 1) * d]);
            if dist < best_d {
                best_d = dist;
                best = c;
            }
        }
        prop_assert!(gi == best, "argmin diverged (d={d}, k={k}): {gi} vs {best}");
        prop_assert!(gd.to_bits() == best_d.to_bits(), "distance bits diverged (d={d}, k={k})");
        Ok(())
    });
}

/// The decode-side determinism contract (tentpole of the parallel
/// serving path): pooled `encode_nearest` / `decode` / `decode_weighted`
/// are bit-identical to serial — including the f64 MSE reduction, which
/// sums per-chunk partials in chunk order on both paths.
#[test]
fn parallel_encode_decode_paths_bit_identical_to_serial() {
    proptest(|g| {
        // d = 8 draws the pruned-scan dispatch into the contract too.
        let d = [1usize, 2, 4, 8][g.usize_in(0, 3)];
        let k = g.usize_in(2, 24);
        let s = g.usize_in(1, 400);
        let threads = g.usize_in(2, 8);
        let cb = Codebook::new(k, d, g.vec_normal((k * d)..=(k * d)));
        let flat = g.vec_normal((s * d)..=(s * d));
        let pool = ThreadPool::new(threads);
        let fbits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();

        let (m1, c1) = cb.encode_nearest_with(&flat, None);
        let (m2, c2) = cb.encode_nearest_with(&flat, Some(&pool));
        prop_assert_eq!(m1.to_bits(), m2.to_bits());
        prop_assert_eq!(c1.clone(), c2);

        let mut o1 = vec![0.0f32; s * d];
        let mut o2 = vec![0.0f32; s * d];
        cb.decode_with(&c1, &mut o1, None);
        cb.decode_with(&c1, &mut o2, Some(&pool));
        prop_assert_eq!(fbits(&o1), fbits(&o2));

        let n = g.usize_in(1, k.min(4));
        let assign: Vec<u32> = (0..s * n).map(|_| g.u32_below(k as u32)).collect();
        let ratios = g.vec_uniform((s * n)..=(s * n), 0.0, 1.0);
        let mut w1 = vec![0.0f32; s * d];
        let mut w2 = vec![0.0f32; s * d];
        cb.decode_weighted_with(&assign, &ratios, n, &mut w1, None);
        cb.decode_weighted_with(&assign, &ratios, n, &mut w2, Some(&pool));
        prop_assert_eq!(fbits(&w1), fbits(&w2));
        Ok(())
    });
}

/// Batched serving decode: pooled output is bit-identical to serial,
/// every decoded row (padded ones included) equals the direct decode of
/// its packed-stream window, and the utilization metric matches the
/// batch's padding accounting.
#[test]
fn batched_packed_decode_parallel_identical_and_rows_correct() {
    let pool = ThreadPool::new(4);
    proptest(|g| {
        let d = [1usize, 2, 4][g.usize_in(0, 2)];
        let k = g.usize_in(2, 16);
        let cb = Codebook::new(k, d, g.vec_normal((k * d)..=(k * d)));
        let codes_per_row = g.usize_in(1, 32);
        let device_rows = g.usize_in(1, 12);
        let bits = (usize::BITS - (k - 1).leading_zeros()).max(1);
        let codes: Vec<u32> = (0..device_rows * codes_per_row)
            .map(|_| g.u32_below(k as u32))
            .collect();
        let staged = StagedCodes::single(pack_codes(&codes, bits));

        let nreq = g.usize_in(1, device_rows);
        let reqs: Vec<Request> = (0..nreq)
            .map(|i| Request {
                id: i as u64,
                net: "n".into(),
                row: g.usize_in(0, device_rows - 1),
                arrived_ns: 0,
                deadline_ns: 0,
            })
            .collect();
        let batch = Batch::form("n", reqs, device_rows);
        prop_assert_eq!(batch.rows.len(), device_rows);
        prop_assert_eq!(batch.padded + batch.requests.len(), batch.rows.len());

        let serial =
            decode_batch(&batch, &staged, &cb, codes_per_row, None).map_err(|e| e.to_string())?;
        let parallel = decode_batch(&batch, &staged, &cb, codes_per_row, Some(&pool))
            .map_err(|e| e.to_string())?;
        let fbits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        prop_assert_eq!(fbits(&serial.weights), fbits(&parallel.weights));
        prop_assert_eq!(serial.codes_unpacked, device_rows * codes_per_row);
        prop_assert!(
            (serial.utilization - batch.utilization()).abs() < 1e-12,
            "utilization {} != {}",
            serial.utilization,
            batch.utilization()
        );

        let stride = codes_per_row * d;
        for (pos, &row) in batch.rows.iter().enumerate() {
            let direct = cb.decode_vec(&codes[row * codes_per_row..(row + 1) * codes_per_row]);
            prop_assert_eq!(
                fbits(&serial.weights[pos * stride..(pos + 1) * stride]),
                fbits(&direct)
            );
        }

        // The streaming path (caller-provided buffer, fused kernel) must
        // produce the exact same bits and accounting as the allocating
        // decode, serial and pooled.
        let mut streamed = vec![0.0f32; batch.rows.len() * stride];
        let s = decode_into(&batch, &staged, &cb, codes_per_row, &mut streamed, Some(&pool))
            .map_err(|e| e.to_string())?;
        prop_assert_eq!(fbits(&streamed), fbits(&serial.weights));
        prop_assert_eq!(s.codes_unpacked, serial.codes_unpacked);
        prop_assert_eq!(s.packed_bytes_read, serial.packed_bytes_read);
        Ok(())
    });
}

/// Engine conservation (tentpole property (a)): every accepted request
/// is dispatched exactly once across shards — no loss, no duplication,
/// no cross-net leakage — and a pooled engine behaves bit-identically
/// to a serial one (same dispatch counts, same cache counters).
#[test]
fn engine_conserves_requests_across_shards_and_matches_serial() {
    let pool = ThreadPool::new(4);
    proptest(|g| {
        let nnets = g.usize_in(1, 5);
        let shards = g.usize_in(1, 4);
        let d = [1usize, 2][g.usize_in(0, 1)];
        let k = g.usize_in(2, 8);
        let cb = Arc::new(Codebook::new(k, d, g.vec_normal((k * d)..=(k * d))));
        let bits = (usize::BITS - (k - 1).leading_zeros()).max(1);
        let mut nets = Vec::new();
        for i in 0..nnets {
            let cpr = g.usize_in(1, 6);
            let rows = g.usize_in(1, 8);
            let codes: Vec<u32> = (0..rows * cpr).map(|_| g.u32_below(k as u32)).collect();
            nets.push(HostedNet {
                name: format!("n{i}"),
                codes: StagedCodes::single(pack_codes(&codes, bits)),
                codebook: cb.clone(),
                codes_per_row: cpr,
                device_batch: g.usize_in(1, 6),
            });
        }
        let cfg = EngineConfig {
            shards,
            cache_bytes: [0, g.usize_in(64, 4096)][g.usize_in(0, 1)],
            max_queue_depth: 0,
            batcher: BatcherConfig {
                max_batch: g.usize_in(1, 8),
                max_linger_ns: 10,
            },
            obs: Default::default(),
        };
        let mut serial = Engine::new(cfg, nets.clone()).map_err(|e| e.to_string())?;
        let mut pooled = Engine::new(cfg, nets.clone()).unwrap();

        let total = g.usize_in(1, 60);
        let mut per_net = vec![0u64; nnets];
        for _ in 0..total {
            let i = g.usize_in(0, nnets - 1);
            let srows = nets[i].codes.count() / nets[i].codes_per_row;
            let row = g.usize_in(0, srows - 1);
            serial.submit(&nets[i].name, row).map_err(|e| e.to_string())?;
            pooled.submit(&nets[i].name, row).unwrap();
            per_net[i] += 1;
            if g.bool() {
                serial.tick(50);
                pooled.tick(50);
                let a = serial.dispatch_round(None).map_err(|e| e.to_string())?;
                let b = pooled.dispatch_round(Some(&pool)).map_err(|e| e.to_string())?;
                prop_assert_eq!(a, b);
            }
        }
        // Rejected submits must not count as accepted.
        prop_assert!(serial.submit("ghost", 0).is_err());
        let oob = nets[0].codes.count() / nets[0].codes_per_row;
        prop_assert!(serial.submit("n0", oob).is_err());

        let a = serial.drain(None).map_err(|e| e.to_string())?;
        let b = pooled.drain(Some(&pool)).map_err(|e| e.to_string())?;
        prop_assert_eq!(a, b);

        for (eng, tag) in [(&serial, "serial"), (&pooled, "pooled")] {
            let (acc, disp, shed) = eng.counters();
            prop_assert_eq!(acc, total as u64);
            prop_assert!(
                disp == total as u64,
                "{tag}: dispatched {disp} of {total} accepted"
            );
            prop_assert!(shed == 0, "{tag}: unbounded plane shed {shed} requests");
            prop_assert_eq!(eng.total_pending(), 0);
            for (i, &want) in per_net.iter().enumerate() {
                let name = format!("n{i}");
                let got: u64 = eng
                    .shards()
                    .iter()
                    .map(|s| s.stats.by_net.get(&name).map(|l| l.served).unwrap_or(0))
                    .sum();
                prop_assert!(got == want, "{tag}: {name} served {got}, submitted {want}");
            }
            for s in eng.shards() {
                // Bounded latency accounting: one sample per served
                // request, nonnegative virtual-clock delays.
                prop_assert_eq!(s.stats.latency_ns.count(), s.stats.served);
                prop_assert!(
                    s.stats.served == 0 || s.stats.latency_ns.min() >= 0.0,
                    "{tag}: negative latency on shard {}",
                    s.id
                );
            }
        }
        // Serial and pooled planes end in identical accounting states.
        prop_assert_eq!(serial.cache_stats(), pooled.cache_stats());
        prop_assert_eq!(serial.totals(), pooled.totals());
        Ok(())
    });
}

/// Admission control (the unified-plane tentpole property): under any
/// per-shard queue-depth budget and arbitrary submit/dispatch
/// interleavings, (a) shed decisions are identical serial vs pooled,
/// (b) `accepted == dispatched + shed` holds per net and engine-wide
/// once drained, and (c) no shed request's row ever reaches a decode
/// (and therefore `infer_hard`) — not even as a padded row.  The decode
/// cache is the observer for (c): on an eviction-free budget every
/// decoded window stays resident, so a shed-only row must be absent.
#[test]
fn engine_admission_sheds_deterministically_and_conserves_per_net() {
    let pool = ThreadPool::new(4);
    proptest(|g| {
        let nnets = g.usize_in(1, 4);
        let shards = g.usize_in(1, 4);
        let d = [1usize, 2][g.usize_in(0, 1)];
        let k = g.usize_in(2, 8);
        let cb = Arc::new(Codebook::new(k, d, g.vec_normal((k * d)..=(k * d))));
        let bits = (usize::BITS - (k - 1).leading_zeros()).max(1);
        let mut nets = Vec::new();
        for i in 0..nnets {
            let cpr = g.usize_in(1, 4);
            let rows = g.usize_in(1, 8);
            let codes: Vec<u32> = (0..rows * cpr).map(|_| g.u32_below(k as u32)).collect();
            nets.push(HostedNet {
                name: format!("n{i}"),
                codes: StagedCodes::single(pack_codes(&codes, bits)),
                codebook: cb.clone(),
                codes_per_row: cpr,
                device_batch: g.usize_in(1, 4),
            });
        }
        let max_queue = g.usize_in(0, 4); // 0 = unbounded is in range too
        let cfg = EngineConfig {
            shards,
            // Eviction-free budget: cache membership witnesses "this
            // row's window was decoded at some point".
            cache_bytes: 1 << 20,
            max_queue_depth: max_queue,
            batcher: BatcherConfig {
                max_batch: g.usize_in(1, 4),
                max_linger_ns: 10,
            },
            obs: Default::default(),
        };
        let mut serial = Engine::new(cfg, nets.clone()).map_err(|e| e.to_string())?;
        let mut pooled = Engine::new(cfg, nets.clone()).unwrap();

        let total = g.usize_in(1, 80);
        let mut offered = vec![0u64; nnets];
        let mut accepted_rows = std::collections::BTreeSet::new();
        let mut shed_rows = std::collections::BTreeSet::new();
        for _ in 0..total {
            let i = g.usize_in(0, nnets - 1);
            let srows = nets[i].codes.count() / nets[i].codes_per_row;
            let row = g.usize_in(0, srows - 1);
            let a = serial.try_submit(&nets[i].name, row).map_err(|e| e.to_string())?;
            let b = pooled.try_submit(&nets[i].name, row).map_err(|e| e.to_string())?;
            prop_assert!(
                a == b,
                "shed decision diverged serial vs pooled: {a:?} vs {b:?}"
            );
            offered[i] += 1;
            match a {
                Admission::Accepted { .. } => {
                    accepted_rows.insert((i, row));
                }
                Admission::Rejected { depth, .. } => {
                    prop_assert!(
                        max_queue > 0 && depth >= max_queue,
                        "shed below budget: depth {depth}, budget {max_queue}"
                    );
                    shed_rows.insert((i, row));
                }
            }
            if g.bool() {
                serial.tick(50);
                pooled.tick(50);
                let a = serial.dispatch_round(None).map_err(|e| e.to_string())?;
                let b = pooled.dispatch_round(Some(&pool)).map_err(|e| e.to_string())?;
                prop_assert_eq!(a, b);
            }
        }
        let a = serial.drain(None).map_err(|e| e.to_string())?;
        let b = pooled.drain(Some(&pool)).map_err(|e| e.to_string())?;
        prop_assert_eq!(a, b);

        for (eng, tag) in [(&serial, "serial"), (&pooled, "pooled")] {
            let (acc, disp, shed) = eng.counters();
            prop_assert_eq!(acc, total as u64);
            prop_assert!(
                acc == disp + shed,
                "{tag}: accepted {acc} != dispatched {disp} + shed {shed}"
            );
            prop_assert_eq!(eng.total_pending(), 0);
            for (i, &want) in offered.iter().enumerate() {
                let name = format!("n{i}");
                let mut ledger = vq4all::serving::NetLedger::default();
                for s in eng.shards() {
                    if let Some(l) = s.stats.by_net.get(&name) {
                        ledger.accepted += l.accepted;
                        ledger.served += l.served;
                        ledger.shed += l.shed;
                    }
                }
                prop_assert!(
                    ledger.accepted == want && ledger.accepted == ledger.served + ledger.shed,
                    "{tag}: {name} ledger {ledger:?} vs {want} offered"
                );
            }
            for s in eng.shards() {
                prop_assert!(
                    max_queue == 0 || s.stats.peak_depth <= max_queue,
                    "{tag}: shard {} backlog {} exceeded the budget {max_queue}",
                    s.id,
                    s.stats.peak_depth
                );
            }
            // (c) shed-only rows were never decoded: their windows are
            // absent from the owning shard's (eviction-free) cache.
            for &(i, row) in shed_rows.difference(&accepted_rows) {
                let name = format!("n{i}");
                let shard = eng
                    .shards()
                    .iter()
                    .find(|s| s.hosts(&name))
                    .expect("hosted net has a shard");
                let cpr = nets[i].codes_per_row;
                let w = RowWindow {
                    net: shard.net_id(&name).expect("hosted net has an id"),
                    start: row * cpr,
                    end: (row + 1) * cpr,
                };
                prop_assert!(
                    !shard.cache.contains(&w),
                    "{tag}: shed request's row {row} of {name} reached a decode"
                );
            }
        }
        prop_assert_eq!(serial.cache_stats(), pooled.cache_stats());
        prop_assert_eq!(serial.totals(), pooled.totals());
        Ok(())
    });
}

/// Observability reconciliation (ISSUE-8 tentpole property): under
/// arbitrary shed / deferral / pre-admission-rejection interleavings,
/// stage counts 1..=3, and any queue budget, `Engine::metrics_snapshot`
/// satisfies the conservation identities it is *defined* to satisfy —
/// `accepted == dispatched + shed` (engine-wide and per net),
/// `cache_hits + cache_misses == cache_lookups`,
/// `queue_ns.count() == dispatched`, events exactly the injected ones —
/// and, because every stamp rides the engine clock, a pooled engine's
/// snapshot and flight-recorder trace are *equal* to the serial one's.
#[test]
fn metrics_snapshot_reconciles_and_is_pool_invariant() {
    use vq4all::serving::engine::RowServe;
    use vq4all::serving::EventKind;
    let pool = ThreadPool::new(4);
    proptest(|g| {
        let nnets = g.usize_in(1, 3);
        let shards = g.usize_in(1, 3);
        let d = [1usize, 2][g.usize_in(0, 1)];
        let k = g.usize_in(2, 8);
        let cb = Arc::new(Codebook::new(k, d, g.vec_normal((k * d)..=(k * d))));
        let idx_bits = (usize::BITS - (k - 1).leading_zeros()).max(1);
        let mut nets = Vec::new();
        for i in 0..nnets {
            let cpr = g.usize_in(1, 4);
            let rows = g.usize_in(1, 8);
            let nstages = g.usize_in(1, 3);
            let staged = StagedCodes::new(
                (0..nstages)
                    .map(|_| {
                        let codes: Vec<u32> =
                            (0..rows * cpr).map(|_| g.u32_below(k as u32)).collect();
                        pack_codes(&codes, idx_bits)
                    })
                    .collect(),
            );
            nets.push(HostedNet {
                name: format!("n{i}"),
                codes: staged,
                codebook: cb.clone(),
                codes_per_row: cpr,
                device_batch: g.usize_in(1, 4),
            });
        }
        let cfg = EngineConfig {
            shards,
            // Eviction-free budget: the only recorded events are the
            // sheds/deferrals/rejections this test injects, so the
            // flight-recorder ledger is exactly predictable.
            cache_bytes: 1 << 20,
            max_queue_depth: g.usize_in(0, 4),
            batcher: BatcherConfig {
                max_batch: g.usize_in(1, 4),
                max_linger_ns: 10,
            },
            obs: Default::default(),
        };
        let mut serial = Engine::new(cfg, nets.clone()).map_err(|e| e.to_string())?;
        let mut pooled = Engine::new(cfg, nets.clone()).unwrap();

        let mut sheds = 0u64;
        let mut deferrals = 0u64;
        let mut rejections = 0u64;
        let mut stage_reports = 0u64;
        let mut decode_total = 0u64;
        let mut infer_total = 0u64;
        for _ in 0..g.usize_in(1, 60) {
            let i = g.usize_in(0, nnets - 1);
            let name = format!("n{i}");
            let srows = nets[i].codes.count() / nets[i].codes_per_row;
            let row = g.usize_in(0, srows - 1);
            let a = serial.try_submit(&name, row).map_err(|e| e.to_string())?;
            let b = pooled.try_submit(&name, row).map_err(|e| e.to_string())?;
            prop_assert!(a == b, "admission diverged: {a:?} vs {b:?}");
            if matches!(a, Admission::Rejected { .. }) {
                sheds += 1;
            }
            if g.bool() {
                // A front-end parking a request instead of shedding it
                // counts one deferral on the owning shard.
                serial.note_deferral(&name);
                pooled.note_deferral(&name);
                deferrals += 1;
            }
            if g.usize_in(0, 9) == 0 {
                // Pre-admission refusal (unknown net / bad row): lands
                // on the flight recorder, never on the conservation
                // counters.
                let kind =
                    [EventKind::HostingError, EventKind::OutOfRangeRow][g.usize_in(0, 1)];
                serial.note_rejected(&name, kind, row as u64, srows as u64);
                pooled.note_rejected(&name, kind, row as u64, srows as u64);
                rejections += 1;
            }
            if g.bool() {
                serial.tick(50);
                pooled.tick(50);
                let x = serial.dispatch_round(None).map_err(|e| e.to_string())?;
                let y = pooled.dispatch_round(Some(&pool)).map_err(|e| e.to_string())?;
                prop_assert_eq!(x, y);
                // The front-end owns the stage clocks; both engines must
                // fold identical reports into identical histograms.
                let serve = RowServe {
                    hits: g.usize_in(0, 4),
                    misses: g.usize_in(0, 4),
                };
                let (dns, ins, rns) = (
                    g.usize_in(0, 5_000) as u64,
                    g.usize_in(1, 5_000) as u64,
                    g.usize_in(0, 500) as u64,
                );
                serial.observe_batch(&name, serve, dns, ins, rns);
                pooled.observe_batch(&name, serve, dns, ins, rns);
                stage_reports += 1;
                decode_total += dns;
                infer_total += ins;
            }
        }
        serial.drain(None).map_err(|e| e.to_string())?;
        pooled.drain(Some(&pool)).map_err(|e| e.to_string())?;

        let ss = serial.metrics_snapshot();
        let ps = pooled.metrics_snapshot();
        prop_assert!(ss == ps, "pooled snapshot diverged from serial");
        prop_assert_eq!(serial.trace_events(), pooled.trace_events());

        // Admission conservation, engine-wide and per net.
        prop_assert_eq!(ss.accepted, ss.dispatched + ss.shed);
        prop_assert_eq!(ss.shed, sheds);
        prop_assert_eq!(ss.deferred, deferrals);
        prop_assert_eq!(ss.pending, 0);
        // One queue-wait sample per dispatched request.
        prop_assert_eq!(ss.queue_ns.count(), ss.dispatched);
        let mut acc = 0u64;
        let mut net_lookups = 0u64;
        let mut net_queue = 0u64;
        for (name, n) in &ss.per_net {
            prop_assert!(
                n.accepted == n.served + n.shed,
                "{name}: per-net ledger does not reconcile ({n:?})"
            );
            prop_assert_eq!(n.pending, 0);
            prop_assert_eq!(n.queue_ns.count(), n.served);
            acc += n.accepted;
            net_lookups += n.rows_hit + n.rows_missed;
            net_queue += n.queue_ns.count();
        }
        prop_assert_eq!(acc, ss.accepted);
        prop_assert_eq!(net_queue, ss.queue_ns.count());
        // Decode plane: every streamed row is a cache lookup, and the
        // per-net hit/miss rows partition the lookups exactly.
        prop_assert_eq!(ss.cache_lookups, ss.cache_hits + ss.cache_misses);
        prop_assert_eq!(ss.rows_from_cache + ss.rows_decoded, ss.cache_lookups);
        prop_assert_eq!(net_lookups, ss.cache_lookups);
        // Flight recorder: eviction-free budget, so the ledger is
        // exactly the injected events — none dropped at this volume.
        prop_assert_eq!(ss.events_recorded, sheds + deferrals + rejections);
        prop_assert_eq!(ss.events_dropped, 0);
        // Below ring capacity, the trace retains every recorded event.
        prop_assert_eq!(serial.trace_events().len() as u64, ss.events_recorded);
        // Stage tracing: one decode/infer/respond sample per front-end
        // report, decode split exactly between the hit/miss histograms,
        // and the decode-hidden-ratio inputs sum the reported values.
        prop_assert_eq!(ss.decode_ns.count(), stage_reports);
        prop_assert_eq!(ss.infer_ns.count(), stage_reports);
        prop_assert_eq!(ss.respond_ns.count(), stage_reports);
        prop_assert_eq!(
            ss.decode_hit_ns.count() + ss.decode_miss_ns.count(),
            stage_reports
        );
        prop_assert_eq!(ss.decode_ns_total, decode_total);
        prop_assert_eq!(ss.infer_ns_total, infer_total);
        prop_assert_eq!(ss.decode_ns.sum(), decode_total);
        prop_assert_eq!(ss.infer_ns.sum(), infer_total);
        Ok(())
    });
}

/// Decode-cache coherence (tentpole property (b)): any interleaving of
/// cached/uncached row reads — across evictions, serial or pooled — is
/// bit-identical to a fresh `decode_batch`, for widths 1..=32 (reusing
/// the width-bias strategy: awkward non-byte widths drawn half the
/// time) and stage counts 1..=3 (the cache key is stage-agnostic: it
/// stores the fully stage-summed block).
#[test]
fn decode_cache_any_interleaving_bit_identical_to_fresh_decode() {
    let pool = ThreadPool::new(4);
    proptest(|g| {
        let d = [1usize, 2, 4][g.usize_in(0, 2)];
        let k = g.usize_in(2, 16);
        let idx_bits = (usize::BITS - (k - 1).leading_zeros()).max(1);
        let cb = Arc::new(Codebook::new(k, d, g.vec_normal((k * d)..=(k * d))));
        let cpr = g.usize_in(1, 16);
        let rows = g.usize_in(1, 10);
        let nstages = g.usize_in(1, 3);
        let staged = StagedCodes::new(
            (0..nstages)
                .map(|_| {
                    let biased = if g.bool() {
                        [3u32, 5, 7, 13][g.usize_in(0, 3)]
                    } else {
                        g.usize_in(1, 32) as u32
                    };
                    // Codes must address < k words, so the width only widens.
                    let bits = biased.max(idx_bits);
                    let codes: Vec<u32> =
                        (0..rows * cpr).map(|_| g.u32_below(k as u32)).collect();
                    pack_codes(&codes, bits)
                })
                .collect(),
        );
        // Budget drawn below the full working set, so evictions happen
        // regularly; 0 (cache off) is in range too.
        let budget = g.usize_in(0, rows * cpr * d * 4);
        let net = HostedNet {
            name: "n".into(),
            codes: staged.clone(),
            codebook: cb.clone(),
            codes_per_row: cpr,
            device_batch: rows,
        };
        let mut engine = Engine::new(
            EngineConfig {
                shards: 1,
                cache_bytes: budget,
                max_queue_depth: 0,
                batcher: BatcherConfig::default(),
                obs: Default::default(),
            },
            vec![net],
        )
        .map_err(|e| e.to_string())?;
        let stride = cpr * d;
        let fbits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();

        for _round in 0..g.usize_in(1, 8) {
            let nrows = g.usize_in(1, rows);
            let pick: Vec<usize> = (0..nrows).map(|_| g.usize_in(0, rows - 1)).collect();
            let mut dst = vec![0.0f32; nrows * stride];
            let use_pool = g.bool();
            engine
                .decode_rows_into(
                    "n",
                    &pick,
                    &mut dst,
                    if use_pool { Some(&pool) } else { None },
                )
                .map_err(|e| e.to_string())?;
            // Fresh decode of the same rows (unpadded batch).
            let reqs: Vec<Request> = pick
                .iter()
                .enumerate()
                .map(|(i, &r)| Request {
                    id: i as u64,
                    net: "n".into(),
                    row: r,
                    arrived_ns: 0,
                    deadline_ns: 0,
                })
                .collect();
            let batch = Batch::form("n", reqs, nrows);
            let fresh = decode_batch(&batch, &staged, &cb, cpr, None).map_err(|e| e.to_string())?;
            prop_assert_eq!(fbits(&dst), fbits(&fresh.weights));
        }
        let cs = engine.cache_stats();
        prop_assert_eq!(cs.lookups, cs.hits + cs.misses);
        Ok(())
    });
}

#[test]
fn host_matmul_matches_naive_and_softmax_normalizes() {
    proptest(|g| {
        let (m, k, n) = (g.usize_in(1, 6), g.usize_in(1, 6), g.usize_in(1, 6));
        let a = g.vec_normal((m * k)..=(m * k));
        let b = g.vec_normal((k * n)..=(k * n));
        let mut out = vec![0.0; m * n];
        ops::matmul(&a, &b, m, k, n, &mut out);
        for i in 0..m {
            for j in 0..n {
                let mut want = 0.0f32;
                for l in 0..k {
                    want += a[i * k + l] * b[l * n + j];
                }
                prop_assert!(
                    (out[i * n + j] - want).abs() < 1e-3,
                    "({i},{j}): {} vs {want}",
                    out[i * n + j]
                );
            }
        }
        let mut x = g.vec_normal((m * n)..=(m * n));
        ops::softmax_rows(&mut x, m, n);
        for i in 0..m {
            let s: f32 = x[i * n..(i + 1) * n].iter().sum();
            prop_assert!((s - 1.0).abs() < 1e-4, "row {i} sums to {s}");
        }
        Ok(())
    });
}

#[test]
fn argmin_n_returns_sorted_by_distance_prefix() {
    proptest(|g| {
        let len = g.usize_in(1, 50);
        let xs = g.vec_normal(len..=len);
        let n = g.usize_in(1, len);
        let idx = ops::argmin_n(&xs, n);
        prop_assert_eq!(idx.len(), n);
        // Values at returned indices are nondecreasing and are the n smallest.
        for w in idx.windows(2) {
            prop_assert!(xs[w[0]] <= xs[w[1]], "not sorted");
        }
        let mut all: Vec<f32> = xs.clone();
        all.sort_by(|a, b| a.partial_cmp(b).unwrap());
        prop_assert!(
            (xs[idx[n - 1]] - all[n - 1]).abs() < 1e-7,
            "n-th smallest mismatch"
        );
        Ok(())
    });
}

#[test]
fn cosine_and_norm_identities() {
    proptest(|g| {
        let len = g.usize_in(1, 32);
        let a = g.vec_normal(len..=len);
        let c = ops::cosine(&a, &a);
        if ops::norm(&a) > 1e-3 {
            prop_assert!((c - 1.0).abs() < 1e-4, "cos(a,a) = {c}");
            let neg: Vec<f32> = a.iter().map(|x| -x).collect();
            let cn = ops::cosine(&a, &neg);
            prop_assert!((cn + 1.0).abs() < 1e-4, "cos(a,-a) = {cn}");
        }
        Ok(())
    });
}

#[test]
fn frechet_distance_zero_for_identical_clouds_and_grows_with_shift() {
    proptest(|g| {
        let n = g.usize_in(20, 200);
        let pts = g.vec_normal((n * 2)..=(n * 2));
        let (mu, cov) = ops::mean_cov_2d(&pts);
        let d0 = ops::frechet_distance_2d(mu, cov, mu, cov);
        prop_assert!(d0.abs() < 1e-3, "FD(x,x) = {d0}");
        let shift = 1.0 + g.f32_in(0.0, 2.0);
        let moved: Vec<f32> = pts.iter().map(|&x| x + shift).collect();
        let (mu2, cov2) = ops::mean_cov_2d(&moved);
        let d1 = ops::frechet_distance_2d(mu, cov, mu2, cov2);
        // Mean shift of `shift` in both dims contributes 2*shift^2.
        prop_assert!(
        d1 >= (2.0 * shift * shift) as f64 * 0.8,
            "FD {d1} too small for shift {shift}"
        );
        Ok(())
    });
}

#[test]
fn area_model_rom_always_denser_than_sram() {
    proptest(|g| {
        let bytes = g.usize_in(1024, 64 << 20);
        let m = AreaModel::default();
        prop_assert!(
            m.rom_mm2(bytes) < m.sram_mm2(bytes),
            "ROM must be denser: {} vs {}",
            m.rom_mm2(bytes),
            m.sram_mm2(bytes)
        );
        // Monotone in bytes.
        prop_assert!(m.rom_mm2(bytes * 2) > m.rom_mm2(bytes), "ROM not monotone");
        prop_assert!(m.sram_mm2(bytes * 2) > m.sram_mm2(bytes), "SRAM not monotone");
        Ok(())
    });
}

/// Tentpole (SIMD gather): the runtime-dispatched wide-row gather and
/// gather-accumulate (`gather_rows_reference` /
/// `gather_rows_add_reference` vs the AVX2/NEON arms) must be
/// bit-identical on every arm this host can run — raw kernels at ragged
/// widths across the 4/7/8/9 dispatch boundaries, and end-to-end through
/// the fused / staged packed decode at pack widths 1..=32, serial and
/// pooled.
#[test]
fn simd_gather_bit_identical_to_scalar_reference() {
    let pool = ThreadPool::new(4);
    proptest(|g| {
        let fb = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        let d = [4usize, 7, 8, 9, 12, 16][g.usize_in(0, 5)];
        let k = g.usize_in(2, 32);
        let words = g.vec_normal((k * d)..=(k * d));
        let len = g.usize_in(0, 300);
        let codes: Vec<u32> = (0..len).map(|_| g.u32_below(k as u32)).collect();
        for level in simd::available_levels() {
            let mut want = vec![0.0f32; len * d];
            let mut got = vec![0.0f32; len * d];
            simd::gather_rows_reference(&words, &codes, d, &mut want);
            simd::gather_rows(level, &words, &codes, d, &mut got);
            prop_assert!(fb(&got) == fb(&want), "{} d={d} gather diverged", level.name());
            // The accumulate twin, on non-zero destinations.
            let base = g.vec_normal((len * d)..=(len * d));
            want.copy_from_slice(&base);
            got.copy_from_slice(&base);
            simd::gather_rows_add_reference(&words, &codes, d, &mut want);
            simd::gather_rows_add(level, &words, &codes, d, &mut got);
            prop_assert!(fb(&got) == fb(&want), "{} d={d} gather_add diverged", level.name());
        }
        // End to end: the fused + staged decodes dispatch through the
        // same kernels at d >= LANES; the pooled decode must stay
        // bit-identical to serial with SIMD in the chunk kernel.
        let cb = Codebook::new(k, d, words);
        let idx_bits = (usize::BITS - (k - 1).leading_zeros()).max(1);
        let bits = (g.usize_in(1, 32) as u32).max(idx_bits);
        let p = pack_codes(&codes, bits);
        let (start, end) = if len == 0 {
            (0, 0)
        } else {
            let a = g.usize_in(0, len - 1);
            (a, g.usize_in(a, len))
        };
        let mut fast = vec![0.0f32; (end - start) * d];
        let mut slow = vec![0.0f32; (end - start) * d];
        cb.decode_packed_into(&p, start, end, &mut fast);
        cb.decode_packed_into_reference(&p, start, end, &mut slow);
        prop_assert!(fb(&fast) == fb(&slow), "fused decode d={d} bits={bits} diverged");
        let staged = StagedCodes::new(vec![p.clone(), pack_codes(&codes, bits)]);
        let mut fast2 = vec![0.0f32; (end - start) * d];
        let mut slow2 = vec![0.0f32; (end - start) * d];
        cb.decode_staged_packed_into(&staged, start, end, &mut fast2);
        cb.decode_staged_packed_into_reference(&staged, start, end, &mut slow2);
        prop_assert!(fb(&fast2) == fb(&slow2), "staged decode d={d} diverged");
        let mut o1 = vec![0.0f32; len * d];
        let mut o2 = vec![0.0f32; len * d];
        cb.decode_with(&codes, &mut o1, None);
        cb.decode_with(&codes, &mut o2, Some(&pool));
        prop_assert!(fb(&o1) == fb(&o2), "pooled decode d={d} diverged from serial");
        Ok(())
    });
}

/// Tentpole (SIMD pruned scan): every arm's lane-order distance kernels
/// (`sq_dist_lanes_reference` / `sq_dist_pruned_lanes_reference` vs the
/// AVX2/NEON arms) must match bit for bit — full sums, pruned
/// accept/reject decisions at adversarial limits (exactly the sum, just
/// below it, zero, randomized) — the level-threaded `nearest_pruned_at`
/// must equal the naive first-min scan on every arm, and the pruned
/// encode must match the brute reference serial and pooled on both sides
/// of the d = 7 / d = 8 boundary.
#[test]
fn simd_pruned_scan_bit_identical_to_scalar_reference() {
    let pool = ThreadPool::new(4);
    proptest(|g| {
        let n = g.usize_in(8, 40);
        let a = g.vec_normal(n..=n);
        let b = if g.bool() { a.clone() } else { g.vec_normal(n..=n) };
        let want = simd::sq_dist_lanes_reference(&a, &b);
        for level in simd::available_levels() {
            let got = simd::sq_dist_lanes(level, &a, &b);
            prop_assert!(got.to_bits() == want.to_bits(), "{} n={n} sum diverged", level.name());
            for limit in [f32::INFINITY, want, want * 0.999, want * g.f32_in(0.0, 1.5), 0.0] {
                let wp = simd::sq_dist_pruned_lanes_reference(&a, &b, limit);
                let gp = simd::sq_dist_pruned_lanes(level, &a, &b, limit);
                prop_assert!(
                    gp.map(f32::to_bits) == wp.map(f32::to_bits),
                    "{} n={n} limit={limit}: pruned scan diverged",
                    level.name()
                );
            }
        }
        // The level-threaded scan vs the naive first-min reference, with
        // planted exact ties, on every available arm.
        let d = [7usize, 8, 12, 16][g.usize_in(0, 3)];
        let k = g.usize_in(1, 32);
        let mut words = g.vec_normal((k * d)..=(k * d));
        if g.bool() && k >= 2 {
            let src = g.usize_in(0, k - 1);
            let dst = g.usize_in(0, k - 1);
            let row: Vec<f32> = words[src * d..(src + 1) * d].to_vec();
            words[dst * d..(dst + 1) * d].copy_from_slice(&row);
        }
        let sub: Vec<f32> = if g.bool() {
            let c = g.usize_in(0, k - 1);
            words[c * d..(c + 1) * d].to_vec()
        } else {
            g.vec_normal(d..=d)
        };
        let norms: Vec<f32> = words.chunks_exact(d).map(|w| ops::dot(w, w)).collect();
        let mut naive_best = 0usize;
        let mut naive_d = f32::INFINITY;
        for c in 0..k {
            let dist = ops::sq_dist(&sub, &words[c * d..(c + 1) * d]);
            if dist < naive_d {
                naive_d = dist;
                naive_best = c;
            }
        }
        for level in simd::available_levels() {
            let (gi, gd) = ops::nearest_pruned_at(level, &sub, &words, &norms);
            prop_assert!(gi == naive_best, "{} d={d} k={k}: argmin diverged", level.name());
            prop_assert!(
                gd.to_bits() == naive_d.to_bits(),
                "{} d={d} k={k}: distance bits diverged",
                level.name()
            );
        }
        // End to end across the prune boundary: d = 7 takes the naive
        // scan, d = 8+ the pruned lane scan — both must reproduce the
        // brute-force reference, serial and pooled.
        let cb = Codebook::new(k, d, words);
        let s = g.usize_in(0, 200);
        let flat = g.vec_normal((s * d)..=(s * d));
        let (m_ref, c_ref) = cb.encode_nearest_reference(&flat);
        let (m_ser, c_ser) = cb.encode_nearest_with(&flat, None);
        prop_assert!(m_ref.to_bits() == m_ser.to_bits(), "serial MSE diverged d={d}");
        prop_assert_eq!(c_ref.clone(), c_ser);
        let (m_par, c_par) = cb.encode_nearest_with(&flat, Some(&pool));
        prop_assert!(m_ref.to_bits() == m_par.to_bits(), "pooled MSE diverged d={d}");
        prop_assert_eq!(c_ref, c_par);
        Ok(())
    });
}

/// Under `--features race-audit` this whole suite runs with the
/// ThreadPool shadow write-set armed — every parallel kernel above is
/// re-checked for disjoint chunk writes at each join.  This marker
/// proves the detector is actually live in that configuration: a
/// deliberately overlapping write plan must be rejected.  The plan is
/// recorded through the public `note_write` hook with fabricated
/// addresses, so no memory is actually raced (and no `unsafe` leaks
/// into this non-allowlisted test file — the contract audit checks).
#[cfg(feature = "race-audit")]
#[test]
fn race_audit_detector_is_armed() {
    use vq4all::util::threadpool::race_audit;
    let pool = ThreadPool::new(1);
    let err = pool
        .parallel_for(32, 8, |_, _| {
            // Every chunk claims the same byte range; the join must
            // report the cross-chunk overlap.
            race_audit::note_write(0x1000, 0x1008);
        })
        .unwrap_err();
    assert!(err.to_string().contains("race-audit"), "got: {err}");
}

/// Chaos conservation (the fault-plane tentpole property): under an
/// *arbitrary* seeded fault plan — decode panics, corrupt windows,
/// slow-ops, shard wedges at any rates — and arbitrary deadlines, the
/// extended identity `accepted == dispatched + shed + expired + failed`
/// holds engine-wide and per net once drained; a pooled plane stays
/// bit-identical to a serial one (same admissions, same ledgers, same
/// cache counters, same flight-recorder event sequence, same firing
/// schedule); and replaying the same seed + plan reproduces the run
/// exactly.  ShardWedge is capped below always-fire so the 64-round
/// wedge tolerance in `Engine::drain` can never trip by construction.
#[cfg(feature = "fault-inject")]
#[test]
fn chaos_conservation_holds_and_replays_bit_identically() {
    use vq4all::serving::engine::EngineTotals;
    use vq4all::serving::faults::{FaultPlan, FaultSite, ALL_SITES};
    let pool = ThreadPool::new(4);
    proptest(|g| {
        let nnets = g.usize_in(1, 4);
        let shards = g.usize_in(1, 3);
        let d = [1usize, 2][g.usize_in(0, 1)];
        let k = g.usize_in(2, 8);
        let cb = Arc::new(Codebook::new(k, d, g.vec_normal((k * d)..=(k * d))));
        let bits = (usize::BITS - (k - 1).leading_zeros()).max(1);
        let mut nets = Vec::new();
        for i in 0..nnets {
            let cpr = g.usize_in(1, 4);
            let rows = g.usize_in(1, 8);
            let codes: Vec<u32> = (0..rows * cpr).map(|_| g.u32_below(k as u32)).collect();
            nets.push(HostedNet {
                name: format!("n{i}"),
                codes: StagedCodes::single(pack_codes(&codes, bits)),
                codebook: cb.clone(),
                codes_per_row: cpr,
                device_batch: g.usize_in(1, 4),
            });
        }
        let cfg = EngineConfig {
            shards,
            cache_bytes: [0, g.usize_in(64, 4096)][g.usize_in(0, 1)],
            max_queue_depth: g.usize_in(0, 4),
            batcher: BatcherConfig {
                max_batch: g.usize_in(1, 4),
                max_linger_ns: 10,
            },
            obs: Default::default(),
        };
        let mut plan = FaultPlan::new(g.usize_in(0, 1 << 30) as u64);
        for site in ALL_SITES {
            let r = g.usize_in(0, 1000) as u16;
            let r = if site == FaultSite::ShardWedge { r.min(500) } else { r };
            plan = plan.with_rate(site, r);
        }
        // Pre-recorded schedule so the exact scenario replays:
        // (net, row, deadline, dispatch-after?).  Deadline 0 = none, a
        // tiny one lapses before any fire, a huge one never lapses.
        let total = g.usize_in(1, 60);
        let mut sched = Vec::with_capacity(total);
        for _ in 0..total {
            let i = g.usize_in(0, nnets - 1);
            let srows = nets[i].codes.count() / nets[i].codes_per_row;
            let row = g.usize_in(0, srows - 1);
            let deadline = [0u64, g.usize_in(1, 40) as u64, 1 << 40][g.usize_in(0, 2)];
            sched.push((i, row, deadline, g.bool()));
        }
        type PerNet = Vec<(String, [u64; 5])>;
        let run = |pool: Option<&ThreadPool>| -> Result<(String, EngineTotals, PerNet), String> {
            let mut eng = Engine::new(cfg, nets.clone()).map_err(|e| e.to_string())?;
            eng.arm_faults(&plan);
            let mut log = String::new();
            for &(i, row, deadline, disp) in &sched {
                // Quarantines turn later submissions into errors — part
                // of the fingerprint, so serial/pooled/replay must agree
                // on exactly which offers were refused.
                match eng.try_submit_deadline(&format!("n{i}"), row, deadline) {
                    Ok(a) => log.push_str(&format!("{a:?};")),
                    Err(e) => log.push_str(&format!("E({e});")),
                }
                if disp {
                    eng.tick(50);
                    let n = eng.dispatch_round(pool).map_err(|e| e.to_string())?;
                    log.push_str(&format!("d{n};"));
                }
            }
            let drained = eng.drain(pool).map_err(|e| e.to_string())?;
            let mut fired = Vec::new();
            for s in eng.shards() {
                for site in ALL_SITES {
                    fired.push(s.faults.as_ref().map(|p| p.fired(site)).unwrap_or(0));
                }
            }
            let mut per_net: PerNet = Vec::new();
            for i in 0..nnets {
                let name = format!("n{i}");
                let mut sums = [0u64; 5];
                for s in eng.shards() {
                    if let Some(l) = s.stats.by_net.get(&name) {
                        sums[0] += l.accepted;
                        sums[1] += l.served;
                        sums[2] += l.shed;
                        sums[3] += l.expired;
                        sums[4] += l.failed;
                    }
                }
                per_net.push((name, sums));
            }
            let fingerprint = format!(
                "{log}|drained={drained}|totals={:?}|cache={:?}|events={:?}|fired={fired:?}",
                eng.totals(),
                eng.cache_stats(),
                eng.trace_events(),
            );
            Ok((fingerprint, eng.totals(), per_net))
        };
        let (fp_serial, t, per_net) = run(None)?;
        let (fp_pooled, _, _) = run(Some(&pool))?;
        let (fp_replay, _, _) = run(Some(&pool))?;
        // (a) pooled bit-identical to serial under the same armed plan.
        prop_assert_eq!(fp_serial.clone(), fp_pooled);
        // (b) same seed + plan => the run replays exactly (ledgers,
        // events, firing schedule).
        prop_assert_eq!(fp_serial, fp_replay);
        // (c) extended conservation, engine-wide and per net, with no
        // request left queued.
        prop_assert!(
            t.accepted == t.served + t.shed + t.expired + t.failed,
            "extended conservation violated: {t:?}"
        );
        for (name, s) in &per_net {
            prop_assert!(
                s[0] == s[1] + s[2] + s[3] + s[4],
                "{name}: per-net conservation violated: {s:?}"
            );
        }
        Ok(())
    });
}

/// Code-stream integrity + quarantine lifecycle (fault-plane tentpole):
/// flipping any single bit of any hosted net's packed stage is always
/// caught by `Engine::verify_hosted`, which quarantines exactly the
/// corrupted net — its rows are never served again (admission refuses,
/// every decode entry point refuses) while sibling nets keep serving.
/// A decode panic quarantines the whole owning shard (queued work failed
/// with structured errors, conservation intact) and
/// `Engine::revive_shard` restores service — but never un-quarantines a
/// net whose stream is still corrupt.
#[cfg(feature = "fault-inject")]
#[test]
fn chaos_corruption_always_caught_and_quarantine_never_serves() {
    use vq4all::serving::faults::{FaultPlan, FaultSite};
    let pool = ThreadPool::new(2);
    proptest(|g| {
        let nnets = g.usize_in(2, 4);
        let shards = g.usize_in(1, 3);
        let d = [1usize, 2][g.usize_in(0, 1)];
        let k = g.usize_in(2, 8);
        let cb = Arc::new(Codebook::new(k, d, g.vec_normal((k * d)..=(k * d))));
        let bits = (usize::BITS - (k - 1).leading_zeros()).max(1);
        let mut nets = Vec::new();
        for i in 0..nnets {
            let cpr = g.usize_in(1, 4);
            let rows = g.usize_in(1, 8);
            let codes: Vec<u32> = (0..rows * cpr).map(|_| g.u32_below(k as u32)).collect();
            nets.push(HostedNet {
                name: format!("n{i}"),
                codes: StagedCodes::single(pack_codes(&codes, bits)),
                codebook: cb.clone(),
                codes_per_row: cpr,
                device_batch: g.usize_in(1, 4),
            });
        }
        let cfg = EngineConfig {
            shards,
            cache_bytes: 1 << 16,
            max_queue_depth: 0,
            batcher: BatcherConfig {
                max_batch: g.usize_in(1, 4),
                max_linger_ns: 10,
            },
            obs: Default::default(),
        };
        let mut eng = Engine::new(cfg, nets.clone()).map_err(|e| e.to_string())?;
        // Pristine streams re-verify clean against the hosting-time sums.
        eng.verify_hosted().map_err(|e| e.to_string())?;

        // Flip one arbitrary bit of one arbitrary net's packed stage.
        let victim = g.usize_in(0, nnets - 1);
        let vname = format!("n{victim}");
        let vshard = eng
            .shards()
            .iter()
            .position(|s| s.hosts(&vname))
            .expect("hosted net has a shard");
        let nbytes = nets[victim].codes.stage(0).data.len();
        let byte = g.usize_in(0, nbytes - 1);
        prop_assert!(
            eng.shards_mut()[vshard].corrupt_net_byte(&vname, 0, byte),
            "corrupt_net_byte missed {vname} byte {byte}"
        );

        // Re-verification always catches it and names the net.
        let err = eng.verify_hosted().unwrap_err().to_string();
        prop_assert!(
            err.contains(&vname),
            "verify_hosted error {err:?} does not name {vname}"
        );
        prop_assert!(eng.quarantined(&vname), "corrupted net not quarantined");
        // The quarantined net never serves a row: admission refuses ...
        prop_assert!(
            eng.try_submit(&vname, 0).is_err(),
            "quarantined net accepted a request"
        );
        // ... and so does the raw decode plane.
        let stride = nets[victim].codes_per_row * d;
        let mut buf = vec![0.0f32; stride];
        let derr = eng.shards_mut()[vshard]
            .decode_rows_into(&vname, &[0], &mut buf, None)
            .unwrap_err()
            .to_string();
        prop_assert!(derr.contains("quarantined"), "decode refused without naming quarantine: {derr}");
        // Sibling nets keep serving through the same plane.
        for i in 0..nnets {
            if i != victim {
                eng.submit(&format!("n{i}"), 0).map_err(|e| e.to_string())?;
            }
        }
        eng.drain(Some(&pool)).map_err(|e| e.to_string())?;

        // A decode panic takes the whole owning shard down ...
        let healthy = (0..nnets)
            .map(|i| format!("n{i}"))
            .find(|n| !eng.quarantined(n))
            .expect("nnets >= 2 leaves a healthy net");
        let hshard = eng
            .shards()
            .iter()
            .position(|s| s.hosts(&healthy))
            .expect("hosted net has a shard");
        eng.arm_faults(&FaultPlan::new(g.usize_in(0, 1000) as u64).with_rate(FaultSite::DecodePanic, 1000));
        eng.submit(&healthy, 0).map_err(|e| e.to_string())?;
        eng.tick(1_000);
        eng.dispatch_round(Some(&pool)).map_err(|e| e.to_string())?;
        prop_assert!(eng.shards()[hshard].is_quarantined(), "panicked shard not quarantined");
        prop_assert!(
            eng.try_submit(&healthy, 0).is_err(),
            "quarantined shard accepted a request"
        );
        let t = eng.totals();
        prop_assert!(
            t.accepted == t.served + t.shed + t.expired + t.failed && t.failed > 0,
            "conservation through quarantine violated: {t:?}"
        );

        // ... and revival restores the shard, but never the corrupt net.
        eng.disarm_faults();
        eng.revive_shard(hshard).map_err(|e| e.to_string())?;
        eng.submit(&healthy, 0).map_err(|e| e.to_string())?;
        let served = eng.drain(Some(&pool)).map_err(|e| e.to_string())?;
        prop_assert!(served >= 1, "revived shard served nothing");
        prop_assert!(eng.quarantined(&vname), "revive must not clear an integrity quarantine");
        Ok(())
    });
}
