#!/usr/bin/env bash
# One-command tier-1 gate + hot-path perf smoke.
#
#   scripts/verify.sh            # build + tests + hotpath bench (smoke)
#   VQ4ALL_BENCH_MS=300 scripts/verify.sh   # longer measurements
#
# The hotpath bench writes BENCH_hotpath.json (serial-vs-parallel
# comparisons for candidate assignment, k-means, KDE density, the PNC
# scan, encode_nearest, bulk packed unpack, the batched serving decode,
# and the serving-engine rows: cold-vs-warm decode cache and 1-vs-N
# shards) into the repo root so successive PRs can diff it.  Any
# comparison row that regresses below 1.0x (parallel slower than serial)
# FAILS the gate; the engine smoke additionally requires cache hit_rate
# > 0 and warm-cache throughput >= cold (engine_cache >= 1.0x at any
# thread count).  The tier-1 pass/fail summary prints LAST so the gate
# is unmissable.
set -uo pipefail
cd "$(dirname "$0")/.."

build_status=FAIL
test_status=FAIL
bench_status=FAIL
speedup_status=SKIP
engine_status=SKIP

echo "== tier-1: cargo build --release =="
if cargo build --release; then build_status=PASS; fi

echo
echo "== tier-1: cargo test -q =="
if [ "$build_status" = PASS ] && cargo test -q; then test_status=PASS; fi

echo
echo "== perf smoke: hotpath bench =="
if [ "$build_status" = PASS ] \
    && VQ4ALL_BENCH_MS="${VQ4ALL_BENCH_MS:-60}" cargo bench --bench hotpath; then
  bench_status=PASS
fi

# Serial-vs-parallel regression gate: every comparisons[] row in the
# bench JSON must hold >= 1.0x (parallel never slower than serial).
# The ROADMAP bar is >= 2x on >= 4 cores; 1.0x is the hard floor that
# fails the gate rather than warns.  Rows measured with < 2 worker
# threads are informational only (parallel == serial + noise there).
bench_json="${VQ4ALL_BENCH_JSON:-BENCH_hotpath.json}"
if [ "$bench_status" = PASS ] && [ -f "$bench_json" ]; then
  if command -v python3 >/dev/null 2>&1; then
    echo
    echo "== speedup gate: serial-vs-parallel >= 1.0x =="
    if VQ4ALL_GATE_JSON="$bench_json" python3 - <<'EOF'
import json, os, sys
doc = json.load(open(os.environ["VQ4ALL_GATE_JSON"]))
comps = doc.get("comparisons", [])
gated = [c for c in comps if c.get("threads", 0) >= 2]
bad = [c for c in gated if c.get("speedup", 0.0) < 1.0]
for c in comps:
    if c in bad:
        tag = "REGRESSION"
    elif c in gated:
        tag = "ok"
    else:
        tag = "info"  # < 2 threads: parallel path is inline, not gated
    print(f"  {tag:<10} {c['name']:<22} {c['speedup']:.2f}x over {c['threads']} threads")
if not comps:
    print("  REGRESSION no comparison rows found in the bench JSON")
if comps and not gated:
    print("  (single-core runner: all rows informational, gate passes)")
sys.exit(1 if (bad or not comps) else 0)
EOF
    then speedup_status=PASS; else speedup_status=FAIL; fi

    # Engine smoke: the serving-engine rows must exist, the warm-cache
    # row must show hit_rate > 0 and warm >= cold throughput (the
    # engine_cache speedup is thread-count independent, so it gates even
    # on single-core runners); the shard row rides the generic >= 1.0x
    # multi-thread gate above.
    echo
    echo "== engine smoke: decode cache + shards =="
    if VQ4ALL_GATE_JSON="$bench_json" python3 - <<'EOF'
import json, os, sys
doc = json.load(open(os.environ["VQ4ALL_GATE_JSON"]))
comps = {c["name"]: c for c in doc.get("comparisons", [])}
bad = False
eng = doc.get("engine")
if eng is None:
    print("  REGRESSION engine summary missing from bench JSON")
    bad = True
else:
    hr = eng.get("cache_hit_rate", 0.0)
    tag = "ok" if hr > 0 else "REGRESSION"
    bad = bad or hr <= 0
    print(f"  {tag:<10} cache hit_rate {hr:.3f} over "
          f"{int(eng.get('cache_hits', 0) + eng.get('cache_misses', 0))} lookups "
          f"(must be > 0); shards in sharded row: {int(eng.get('shards', 0))}")
for name in ("engine_cache", "engine_shards"):
    c = comps.get(name)
    if c is None:
        print(f"  REGRESSION comparison row {name!r} missing")
        bad = True
        continue
    if name == "engine_cache":
        ok = c["speedup"] >= 1.0
        tag = "ok" if ok else "REGRESSION"
        bad = bad or not ok
        print(f"  {tag:<10} {name:<22} warm/cold {c['speedup']:.2f}x (must be >= 1.0)")
    else:
        print(f"  {'ok':<10} {name:<22} {c['speedup']:.2f}x over {c['threads']} threads "
              "(gated by the generic >= 1.0x rule)")
sys.exit(1 if bad else 0)
EOF
    then engine_status=PASS; else engine_status=FAIL; fi
  else
    echo "python3 unavailable; speedup gate skipped"
  fi
fi

echo
echo "== summary (tier-1 last) =="
echo "  perf smoke (hotpath bench):   $bench_status"
echo "  speedup >= 1.0x gate:         $speedup_status"
echo "  engine smoke (cache+shards):  $engine_status"
echo "  tier-1: cargo build:          $build_status"
echo "  tier-1: cargo test:           $test_status"

if [ "$build_status" = PASS ] && [ "$test_status" = PASS ] \
    && [ "$bench_status" = PASS ] && [ "$speedup_status" != FAIL ] \
    && [ "$engine_status" != FAIL ]; then
  echo "verify OK"
  exit 0
fi
echo "verify FAILED"
exit 1
