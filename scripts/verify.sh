#!/usr/bin/env bash
# One-command tier-1 gate + hot-path perf smoke.
#
#   scripts/verify.sh            # build + tests + hotpath bench (smoke)
#   VQ4ALL_BENCH_MS=300 scripts/verify.sh   # longer measurements
#
# The hotpath bench writes BENCH_hotpath.json (serial-vs-parallel
# comparisons for candidate assignment, k-means, KDE density, and the
# PNC scan) into the repo root so successive PRs can diff it.
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== tier-1: cargo build --release =="
cargo build --release

echo "== tier-1: cargo test -q =="
cargo test -q

echo "== perf smoke: hotpath bench =="
VQ4ALL_BENCH_MS="${VQ4ALL_BENCH_MS:-60}" cargo bench --bench hotpath

echo "verify OK"
