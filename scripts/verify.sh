#!/usr/bin/env bash
# One-command tier-1 gate + hot-path perf smoke.
#
#   scripts/verify.sh                    # build + tests + hotpath bench + JSON gates
#   scripts/verify.sh --check-json       # ... + row-set diff against the committed baseline
#   scripts/verify.sh --gates-only [J]   # only the JSON gates, against J
#                                        #   (default: $VQ4ALL_BENCH_JSON / BENCH_hotpath.json)
#   scripts/verify.sh --audit            # only the repo-native contract audit
#                                        #   (cargo run --bin audit; standalone —
#                                        #   combines with no other flag)
#   VQ4ALL_BENCH_MS=300 scripts/verify.sh        # longer measurements
#
# Flags are validated strictly: unknown flags, duplicate flags, and
# conflicting combinations (--audit with anything else) exit 2 with the
# usage line instead of silently running the wrong mode.
#
# Environment overrides:
#   VQ4ALL_BENCH_MS       per-bench measurement budget in ms (default 60)
#   VQ4ALL_BENCH_JSON     where the hotpath bench writes (and the gates
#                         read) the report — default BENCH_hotpath.json
#   VQ4ALL_BASELINE_JSON  committed row manifest --check-json diffs the
#                         fresh report against — default
#                         scripts/bench_baseline.json (names/keys only;
#                         timings are machine-local and never compared
#                         across files)
#
# The hotpath bench writes serial-vs-parallel comparisons for the VQ and
# serving hot paths plus the serving-engine rows (cold-vs-warm decode
# cache, 1-vs-N shards, bounded-vs-unbounded admission) and the
# legacy-vs-specialized kernel rows (word-level unpack, word-level pack,
# pruned encode, fused decode, staged residual encode/decode, and the
# scalar-reference-vs-dispatched SIMD rows simd_gather / simd_scan).
# Gates:
#   * any comparison row measured on >= 2 worker threads below 1.0x FAILS
#   * the kernel rows (unpack_wordwise, encode_pruned, fused_decode,
#     pack_wordwise, staged_encode, staged_decode, simd_gather,
#     simd_scan) must
#     exist and hold >= 1.0x at ANY thread count (they compare two
#     single-threaded kernels, so thread count is irrelevant; the simd
#     rows additionally assert bit-identity in-bench, and pin the
#     dispatched side to the scalar reference — exactly 1.0x — on hosts
#     with no vector arm, so the row can never silently vanish)
#   * the engine summary must exist with cache hit_rate > 0,
#     engine_cache >= 1.0x (warm never slower than cold, any thread
#     count), extended admission conservation
#     (admission_accepted == admission_dispatched + admission_shed
#      + admission_expired + admission_failed, with shed > 0 and the
#      expired/failed terms zero in the fault-free bench),
#     and absolute throughput keys rows_per_sec / codes_per_sec > 0
#   * the obs_overhead row (uninstrumented vs instrumented warm
#     stream_batch, single-threaded so the generic rule skips it) must
#     exist and hold >= 0.95x — full metrics/tracing may cost at most
#     5% of hot-path throughput — and the obs engine keys must
#     reconcile (obs_queue_count == admission_dispatched, obs_events
#     > 0 with obs_events_dropped reported, obs_decode_hidden_ratio
#     present)
#   * the faults_overhead row (fault plan disarmed vs armed at rate 0,
#     single-threaded) must exist and hold >= 0.95x — the injection
#     probes and deadline checks threaded through the dispatch path may
#     cost at most 5% of warm stream_batch throughput
#   * --check-json additionally FAILS if the fresh report lost any
#     comparison row or engine-summary key the committed baseline lists
# Exit-code contract (the PR-4 bugfix): once the bench has PASSed, the
# JSON gates MUST run and PASS — a missing report, missing python3, or a
# failing engine gate fails the script even when tier-1 is green.  The
# tier-1 pass/fail summary prints LAST so the gate is unmissable.
set -uo pipefail
cd "$(dirname "$0")/.."

usage() {
  echo "usage: scripts/verify.sh [--check-json] [--gates-only [bench.json]]" >&2
  echo "       scripts/verify.sh --audit" >&2
  exit 2
}

mode=full
check_json=0
gates_json=""
audit=0
while [ $# -gt 0 ]; do
  case "$1" in
    --check-json)
      if [ "$check_json" = 1 ]; then
        echo "duplicate flag: --check-json" >&2
        usage
      fi
      check_json=1
      ;;
    --gates-only)
      if [ "$mode" = gates ]; then
        echo "duplicate flag: --gates-only" >&2
        usage
      fi
      mode=gates
      if [ $# -gt 1 ] && [ "${2#--}" = "$2" ]; then
        gates_json="$2"
        shift
      fi
      ;;
    --audit)
      if [ "$audit" = 1 ]; then
        echo "duplicate flag: --audit" >&2
        usage
      fi
      audit=1
      ;;
    *)
      echo "unknown argument: $1" >&2
      usage
      ;;
  esac
  shift
done

if [ "$audit" = 1 ] && { [ "$check_json" = 1 ] || [ "$mode" = gates ]; }; then
  echo "conflicting flags: --audit runs standalone" >&2
  usage
fi

if [ "$audit" = 1 ]; then
  # Standalone contract audit: SAFETY comments, the unsafe-module
  # allow-list, reference-kernel coverage, float accumulation in
  # parallel_for closures.  Env overrides (VQ4ALL_AUDIT_ROOT,
  # VQ4ALL_AUDIT_BASELINE, VQ4ALL_AUDIT_EXTRA_ALLOW) pass through to the
  # binary — CI uses them to seed violations.
  echo "== contract audit: cargo run --release --bin audit =="
  if cargo run --release --bin audit; then
    echo
    echo "== summary (mode: audit) =="
    echo "  contract audit:               PASS"
    echo "verify OK"
    exit 0
  fi
  echo
  echo "== summary (mode: audit) =="
  echo "  contract audit:               FAIL"
  echo "verify FAILED"
  exit 1
fi

build_status=SKIP
test_status=SKIP
bench_status=SKIP
speedup_status=SKIP
engine_status=SKIP
diff_status=SKIP

bench_json="${VQ4ALL_BENCH_JSON:-BENCH_hotpath.json}"
baseline_json="${VQ4ALL_BASELINE_JSON:-scripts/bench_baseline.json}"
if [ "$mode" = gates ] && [ -n "$gates_json" ]; then
  bench_json="$gates_json"
fi

if [ "$mode" = full ]; then
  build_status=FAIL
  test_status=FAIL
  bench_status=FAIL

  echo "== tier-1: cargo build --release =="
  if cargo build --release; then build_status=PASS; fi

  echo
  echo "== tier-1: cargo test -q =="
  if [ "$build_status" = PASS ] && cargo test -q; then test_status=PASS; fi

  echo
  echo "== perf smoke: hotpath bench =="
  if [ "$build_status" = PASS ] \
      && VQ4ALL_BENCH_MS="${VQ4ALL_BENCH_MS:-60}" \
         VQ4ALL_BENCH_JSON="$bench_json" cargo bench --bench hotpath; then
    bench_status=PASS
  fi
fi

run_gates=0
if [ "$mode" = gates ]; then run_gates=1; fi
if [ "$mode" = full ] && [ "$bench_status" = PASS ]; then run_gates=1; fi

if [ "$run_gates" = 1 ]; then
  # A bench that PASSed but left no readable report — or a machine that
  # cannot evaluate the gates — is a FAILURE, not a skip: the gates are
  # the point of the script.
  speedup_status=FAIL
  engine_status=FAIL
  if [ "$check_json" = 1 ]; then diff_status=FAIL; fi
  if ! command -v python3 >/dev/null 2>&1; then
    echo
    echo "ERROR: python3 is required to evaluate the bench JSON gates" >&2
  elif [ ! -f "$bench_json" ]; then
    echo
    echo "ERROR: bench report $bench_json does not exist" >&2
  else
    # Serial-vs-parallel regression gate: every comparisons[] row in the
    # bench JSON must hold >= 1.0x (parallel never slower than serial).
    # The ROADMAP bar is >= 2x on >= 4 cores; 1.0x is the hard floor.
    # Rows measured with < 2 worker threads are informational only
    # (parallel == serial + noise there).
    echo
    echo "== speedup gate: serial-vs-parallel >= 1.0x =="
    if VQ4ALL_GATE_JSON="$bench_json" python3 - <<'EOF'
import json, os, sys
doc = json.load(open(os.environ["VQ4ALL_GATE_JSON"]))
comps = doc.get("comparisons", [])
gated = [c for c in comps if c.get("threads", 0) >= 2]
bad = [c for c in gated if c.get("speedup", 0.0) < 1.0]
for c in comps:
    if c in bad:
        tag = "REGRESSION"
    elif c in gated:
        tag = "ok"
    else:
        tag = "info"  # < 2 threads: parallel path is inline, not gated
    print(f"  {tag:<10} {c['name']:<22} {c['speedup']:.2f}x over {c['threads']} threads")
if not comps:
    print("  REGRESSION no comparison rows found in the bench JSON")
if comps and not gated:
    print("  (single-core runner: all rows informational, gate passes)")
sys.exit(1 if (bad or not comps) else 0)
EOF
    then speedup_status=PASS; else speedup_status=FAIL; fi

    # Engine + kernel smoke: the serving-engine rows must exist; the
    # warm-cache row must show hit_rate > 0 and warm >= cold throughput
    # (thread-count independent, so it gates even on single-core
    # runners); the admission summary must conserve (accepted ==
    # dispatched + shed) with a nonzero shed from the bounded run; the
    # absolute-throughput keys must be present and positive; and the
    # legacy-vs-specialized kernel rows must exist and hold >= 1.0x at
    # any thread count (specialized kernels never slower than the
    # retained references).  The shard/admission rows additionally ride
    # the generic >= 1.0x multi-thread gate.
    # The observability plane adds its own contract: the obs_overhead
    # row compares the same warm stream_batch with obs disabled vs
    # enabled (threads: 1 — two configs of one engine, so the generic
    # multi-thread rule never gates it) and must hold >= 0.95x; the obs
    # engine keys must reconcile with the admission ledger
    # (obs_queue_count == admission_dispatched: one queue-wait sample
    # per dispatched request) and the bounded run must have recorded
    # its sheds on the flight recorder (obs_events > 0).
    echo
    echo "== engine + kernel smoke: decode cache + shards + admission + specialized kernels + obs + faults =="
    if VQ4ALL_GATE_JSON="$bench_json" python3 - <<'EOF'
import json, os, sys
doc = json.load(open(os.environ["VQ4ALL_GATE_JSON"]))
comps = {c["name"]: c for c in doc.get("comparisons", [])}
bad = False
eng = doc.get("engine")
if eng is None:
    print("  REGRESSION engine summary missing from bench JSON")
    bad = True
else:
    hr = eng.get("cache_hit_rate", 0.0)
    tag = "ok" if hr > 0 else "REGRESSION"
    bad = bad or hr <= 0
    print(f"  {tag:<10} cache hit_rate {hr:.3f} over "
          f"{int(eng.get('cache_hits', 0) + eng.get('cache_misses', 0))} lookups "
          f"(must be > 0); shards in sharded row: {int(eng.get('shards', 0))}")
    acc = eng.get("admission_accepted")
    disp = eng.get("admission_dispatched")
    shed = eng.get("admission_shed")
    exp = eng.get("admission_expired")
    flr = eng.get("admission_failed")
    if acc is None or disp is None or shed is None or exp is None or flr is None:
        print("  REGRESSION admission counters missing from the engine summary "
              "(accepted/dispatched/shed/expired/failed must all be present)")
        bad = True
    else:
        conserves = int(acc) == int(disp) + int(shed) + int(exp) + int(flr)
        nonzero = int(shed) > 0
        clean = int(exp) == 0 and int(flr) == 0
        tag = "ok" if (conserves and nonzero and clean) else "REGRESSION"
        bad = bad or not (conserves and nonzero and clean)
        print(f"  {tag:<10} admission {int(acc)} accepted == {int(disp)} dispatched "
              f"+ {int(shed)} shed + {int(exp)} expired + {int(flr)} failed "
              "(extended conservation; bounded run must shed; fault-free bench "
              "must not expire or fail)")
    for key in ("rows_per_sec", "codes_per_sec"):
        v = eng.get(key)
        if v is None or v <= 0:
            print(f"  REGRESSION absolute throughput key {key!r} missing or <= 0: {v}")
            bad = True
        else:
            print(f"  {'ok':<10} engine {key} = {v:.0f} (absolute, machine-local)")
    qc = eng.get("obs_queue_count")
    if qc is None or disp is None or int(qc) != int(disp):
        print(f"  REGRESSION obs_queue_count {qc} != admission_dispatched {disp} "
              "(one queue-wait sample per dispatched request)")
        bad = True
    else:
        print(f"  {'ok':<10} obs_queue_count {int(qc)} == admission_dispatched (snapshot reconciles)")
    ev = eng.get("obs_events")
    dropped = eng.get("obs_events_dropped")
    if ev is None or dropped is None or int(ev) <= 0 or int(dropped) < 0:
        print(f"  REGRESSION obs_events {ev} (must be > 0: the bounded run sheds) "
              f"/ obs_events_dropped {dropped} (must be reported)")
        bad = True
    else:
        print(f"  {'ok':<10} flight recorder: {int(ev)} events recorded, {int(dropped)} dropped")
    dh = eng.get("obs_decode_hidden_ratio")
    if dh is None or dh < 0:
        print(f"  REGRESSION obs_decode_hidden_ratio missing or negative: {dh}")
        bad = True
    else:
        print(f"  {'ok':<10} obs_decode_hidden_ratio = {dh:.3f} (informational, must exist)")
for name in ("engine_cache", "engine_shards", "engine_admission"):
    c = comps.get(name)
    if c is None:
        print(f"  REGRESSION comparison row {name!r} missing")
        bad = True
        continue
    if name == "engine_cache":
        ok = c["speedup"] >= 1.0
        tag = "ok" if ok else "REGRESSION"
        bad = bad or not ok
        print(f"  {tag:<10} {name:<22} warm/cold {c['speedup']:.2f}x (must be >= 1.0)")
    else:
        print(f"  {'ok':<10} {name:<22} {c['speedup']:.2f}x over {c['threads']} threads "
              "(gated by the generic >= 1.0x rule)")
for name in ("unpack_wordwise", "encode_pruned", "fused_decode",
             "pack_wordwise", "staged_encode", "staged_decode",
             "simd_gather", "simd_scan"):
    c = comps.get(name)
    if c is None:
        print(f"  REGRESSION kernel row {name!r} missing")
        bad = True
        continue
    ok = c["speedup"] >= 1.0
    tag = "ok" if ok else "REGRESSION"
    bad = bad or not ok
    print(f"  {tag:<10} {name:<22} legacy/specialized {c['speedup']:.2f}x "
          "(must be >= 1.0 at any thread count)")
c = comps.get("obs_overhead")
if c is None:
    print("  REGRESSION comparison row 'obs_overhead' missing")
    bad = True
else:
    ok = c["speedup"] >= 0.95
    tag = "ok" if ok else "REGRESSION"
    bad = bad or not ok
    print(f"  {tag:<10} {'obs_overhead':<22} obs-off/obs-on {c['speedup']:.2f}x "
          "(instrumentation may cost at most 5% of warm stream_batch)")
c = comps.get("faults_overhead")
if c is None:
    print("  REGRESSION comparison row 'faults_overhead' missing")
    bad = True
else:
    ok = c["speedup"] >= 0.95
    tag = "ok" if ok else "REGRESSION"
    bad = bad or not ok
    print(f"  {tag:<10} {'faults_overhead':<22} disarmed/armed-at-0 {c['speedup']:.2f}x "
          "(fault probes + deadline checks may cost at most 5% of warm stream_batch)")
sys.exit(1 if bad else 0)
EOF
    then engine_status=PASS; else engine_status=FAIL; fi

    if [ "$check_json" = 1 ]; then
      # Row-set diff against the committed baseline manifest: the fresh
      # report may add rows/keys, but losing any that the baseline lists
      # is a regression (a silently dropped bench row would otherwise
      # pass every numeric gate).  Values in the baseline are ignored —
      # timings are machine-local.
      echo
      echo "== check-json: fresh report vs committed baseline =="
      if [ ! -f "$baseline_json" ]; then
        echo "ERROR: baseline $baseline_json does not exist (set VQ4ALL_BASELINE_JSON)" >&2
        diff_status=FAIL
      elif VQ4ALL_GATE_JSON="$bench_json" VQ4ALL_BASELINE="$baseline_json" python3 - <<'EOF'
import json, os, sys
fresh = json.load(open(os.environ["VQ4ALL_GATE_JSON"]))
base = json.load(open(os.environ["VQ4ALL_BASELINE"]))
bad = False
fresh_rows = {c.get("name") for c in fresh.get("comparisons", [])}
for c in base.get("comparisons", []):
    name = c.get("name")
    tag = "ok" if name in fresh_rows else "REGRESSION"
    bad = bad or name not in fresh_rows
    print(f"  {tag:<10} comparison row {name!r}")
fresh_eng = fresh.get("engine") or {}
for key in (base.get("engine") or {}):
    tag = "ok" if key in fresh_eng else "REGRESSION"
    bad = bad or key not in fresh_eng
    print(f"  {tag:<10} engine summary key {key!r}")
extra = fresh_rows - {c.get("name") for c in base.get("comparisons", [])}
if extra:
    print(f"  note: fresh rows not in the baseline yet (add them): {sorted(extra)}")
sys.exit(1 if bad else 0)
EOF
      then diff_status=PASS; else diff_status=FAIL; fi
    fi
  fi
fi

echo
echo "== summary (mode: $mode; tier-1 last) =="
echo "  perf smoke (hotpath bench):   $bench_status"
echo "  speedup >= 1.0x gate:         $speedup_status"
echo "  engine+kernel smoke (cache+shards+admission+specialized+obs+faults): $engine_status"
echo "  check-json baseline diff:     $diff_status"
echo "  tier-1: cargo build:          $build_status"
echo "  tier-1: cargo test:           $test_status"

ok=1
for s in "$build_status" "$test_status" "$bench_status" \
         "$speedup_status" "$engine_status" "$diff_status"; do
  if [ "$s" = FAIL ]; then ok=0; fi
done
if [ "$mode" = full ]; then
  # Tier-1 + bench must PASS, and the gates must have actually RUN and
  # passed — SKIP is only acceptable for an unrequested --check-json.
  if [ "$build_status" != PASS ] || [ "$test_status" != PASS ] \
      || [ "$bench_status" != PASS ] || [ "$speedup_status" != PASS ] \
      || [ "$engine_status" != PASS ]; then
    ok=0
  fi
  if [ "$check_json" = 1 ] && [ "$diff_status" != PASS ]; then ok=0; fi
else
  if [ "$speedup_status" != PASS ] || [ "$engine_status" != PASS ]; then ok=0; fi
  if [ "$check_json" = 1 ] && [ "$diff_status" != PASS ]; then ok=0; fi
fi

if [ "$ok" = 1 ]; then
  echo "verify OK"
  exit 0
fi
echo "verify FAILED"
exit 1
