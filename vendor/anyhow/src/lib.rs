//! Minimal offline stand-in for the `anyhow` crate.
//!
//! The build image has no registry access, so this vendored shim provides
//! the small API surface the workspace actually uses:
//!
//! * [`Error`] — a boxed, message-carrying error type.  Like the real
//!   `anyhow::Error` it deliberately does **not** implement
//!   `std::error::Error`, which is what makes the blanket
//!   `From<E: std::error::Error>` conversion (and therefore `?` on any
//!   std error) coherent.
//! * [`Result<T>`] — `Result<T, Error>` with a defaulted error parameter.
//! * [`anyhow!`], [`bail!`], [`ensure!`] — the formatting macros.
//!
//! Context chaining (`.context(...)`) is intentionally omitted: the
//! workspace formats context into messages at the call site instead.

use std::fmt;

/// A message-carrying error.  Construction is cheap (one `String`); the
/// original error's `Display` output is captured at conversion time.
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from anything displayable (what `anyhow!` expands to).
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error {
            msg: message.to_string(),
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // Debug mirrors Display so `main() -> anyhow::Result<()>` prints
        // the human message, matching real-anyhow behaviour closely
        // enough for this workspace.
        f.write_str(&self.msg)
    }
}

// `Error` itself must NOT implement `std::error::Error`, or this blanket
// impl would overlap with the reflexive `From<Error> for Error`.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        Error { msg: e.to_string() }
    }
}

/// `Result` with the error type defaulted to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from format arguments.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with a formatted [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails_io() -> Result<()> {
        std::fs::read_to_string("/definitely/not/a/real/path/vq4all")?;
        Ok(())
    }

    fn bails(flag: bool) -> Result<u32> {
        ensure!(flag, "flag was {flag}");
        if !flag {
            bail!("unreachable");
        }
        Ok(7)
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let err = fails_io().unwrap_err();
        assert!(!err.to_string().is_empty());
    }

    #[test]
    fn macros_format_and_shortcircuit() {
        assert_eq!(bails(true).unwrap(), 7);
        let e = bails(false).unwrap_err();
        assert_eq!(e.to_string(), "flag was false");
        let e2: Error = anyhow!("x = {}", 42);
        assert_eq!(format!("{e2}"), "x = 42");
        assert_eq!(format!("{e2:?}"), "x = 42");
    }

    #[test]
    fn collects_into_result() {
        let ok: Result<Vec<u32>> = (0u32..3).map(Ok).collect();
        assert_eq!(ok.unwrap(), vec![0, 1, 2]);
    }
}
