//! Host-only compile stub for the `xla` (PJRT) crate.
//!
//! The build image does not ship the xla_extension shared library, so this
//! vendored stub keeps the workspace compiling with the exact API shape the
//! coordinator uses.  The split:
//!
//! * **Literals are real.**  [`Literal`] stores element type + dims + raw
//!   little-endian bytes, so host-side marshalling code
//!   (`tensor_to_literal` / `literal_to_tensor`) works and is testable.
//! * **The runtime is gated.**  [`PjRtClient::cpu`] and
//!   [`HloModuleProto::from_text_file`] return [`XlaError`], so everything
//!   that needs a live PJRT backend fails fast with a clear message and
//!   the integration tests skip instead of crashing.
//!
//! Swapping in a real `xla` build is a Cargo.toml change only — the
//! signatures below match the xla_extension 0.5.x wrapper the code was
//! written against.

use std::borrow::Borrow;
use std::fmt;
use std::path::Path;

/// Error type; formatted with `{:?}` at every call site.
#[derive(Clone)]
pub struct XlaError(pub String);

impl fmt::Debug for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for XlaError {}

pub type Result<T> = std::result::Result<T, XlaError>;

fn unavailable(what: &str) -> XlaError {
    XlaError(format!(
        "{what}: PJRT runtime unavailable — this build vendors the host-only \
         xla stub (vendor/xla); install xla_extension and point Cargo at the \
         real crate to run device paths"
    ))
}

/// Element types the coordinator marshals.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
    U32,
    F64,
    S64,
    U8,
}

impl ElementType {
    pub fn size_bytes(self) -> usize {
        match self {
            ElementType::F32 | ElementType::S32 | ElementType::U32 => 4,
            ElementType::F64 | ElementType::S64 => 8,
            ElementType::U8 => 1,
        }
    }
}

/// Host types that can be read out of a [`Literal`].
pub trait NativeType: Copy {
    const TY: ElementType;
    fn from_le(bytes: &[u8]) -> Self;
}

macro_rules! native {
    ($ty:ty, $variant:ident, $w:expr) => {
        impl NativeType for $ty {
            const TY: ElementType = ElementType::$variant;
            fn from_le(bytes: &[u8]) -> Self {
                <$ty>::from_le_bytes(bytes.try_into().expect("element width"))
            }
        }
    };
}

native!(f32, F32, 4);
native!(i32, S32, 4);
native!(u32, U32, 4);
native!(f64, F64, 8);
native!(i64, S64, 8);
native!(u8, U8, 1);

/// A host literal: element type, dims, raw little-endian payload.
#[derive(Clone, Debug)]
pub struct Literal {
    ty: ElementType,
    dims: Vec<usize>,
    data: Vec<u8>,
}

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        ty: ElementType,
        dims: &[usize],
        data: &[u8],
    ) -> Result<Literal> {
        let elems: usize = dims.iter().product();
        if elems * ty.size_bytes() != data.len() {
            return Err(XlaError(format!(
                "literal payload is {} bytes, shape {dims:?} of {ty:?} needs {}",
                data.len(),
                elems * ty.size_bytes()
            )));
        }
        Ok(Literal {
            ty,
            dims: dims.to_vec(),
            data: data.to_vec(),
        })
    }

    pub fn element_type(&self) -> ElementType {
        self.ty
    }

    pub fn dims(&self) -> &[usize] {
        &self.dims
    }

    pub fn element_count(&self) -> usize {
        self.dims.iter().product()
    }

    /// Decode the payload as a typed vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        if self.ty != T::TY {
            return Err(XlaError(format!(
                "literal holds {:?}, asked for {:?}",
                self.ty,
                T::TY
            )));
        }
        let w = self.ty.size_bytes();
        Ok(self.data.chunks_exact(w).map(T::from_le).collect())
    }

    /// Decompose a tuple result.  Only device executions produce tuples,
    /// and those are gated behind the stubbed runtime.
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(unavailable("Literal::to_tuple"))
    }
}

/// PJRT client handle — creation always fails in the stub.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }
}

/// Parsed HLO module — text loading is gated (needs the real parser).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(path: P) -> Result<HloModuleProto> {
        Err(unavailable(&format!(
            "HloModuleProto::from_text_file({:?})",
            path.as_ref()
        )))
    }
}

/// Computation wrapper.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Compiled executable — unreachable through the stubbed client, but the
/// type and `execute` signature must exist for the wrapper to compile.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L: Borrow<Literal>>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// Device buffer handle.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let xs = [1.5f32, -2.0, 0.25];
        let bytes: Vec<u8> = xs.iter().flat_map(|x| x.to_le_bytes()).collect();
        let l = Literal::create_from_shape_and_untyped_data(ElementType::F32, &[3], &bytes)
            .unwrap();
        assert_eq!(l.to_vec::<f32>().unwrap(), xs);
        assert_eq!(l.element_count(), 3);
        assert!(l.to_vec::<i32>().is_err(), "dtype mismatch must error");
    }

    #[test]
    fn literal_size_checked() {
        assert!(
            Literal::create_from_shape_and_untyped_data(ElementType::F32, &[2], &[0u8; 7])
                .is_err()
        );
    }

    #[test]
    fn runtime_paths_are_gated() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
    }
}
